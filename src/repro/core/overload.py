"""Serving-layer survival kit: admission control, throttling, circuit
breaking, and graceful degradation for overloaded CURP servers.

These are the protocol-agnostic policy objects behind the "production
traffic armor" scenarios (repro.sim's open-loop storms, benchmarks/fig_slo):

* ``AdmissionQueue`` — queue-based load leveling in front of a single-server
  node: a bounded count of delivered-but-not-yet-served messages.  Arrivals
  beyond the bound are shed *immediately* (fail fast) instead of joining a
  queue whose wait already exceeds any useful deadline.  The shed reply is
  explicit, so clients back off rather than timing out and retrying into
  the same overload.
* ``TokenBucket`` / ``ClientThrottle`` — per-client rate limiting at the
  server: one misbehaving (or retry-storming) client cannot claim more than
  its provisioned share of admission slots.
* ``CircuitBreaker`` — client-side per-shard failure accounting: trips OPEN
  after consecutive failures (timeouts, NOT_OWNER on a mid-migration slot,
  crashed-master silence), fails fast while OPEN, and re-probes with a
  bounded number of HALF_OPEN trial requests after a cooldown.
* ``DegradeLevel``/``degrade_level`` — graceful degradation policy: under
  pressure the server sheds *slow-path* work first (defer batched backup
  syncs and witness gc), keeping the witness-backed 1-RTT write path alive;
  conflict-path syncs that gate withheld client replies are never deferred.

All times are caller-supplied floats (the discrete-event sim passes
``sim.now`` in µs); nothing here reads a wall clock, so the objects are
deterministic under simulation and trivially unit-testable.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Optional

from .telemetry import Histogram, get_registry


# --------------------------------------------------------------------------
# Admission control (queue-based load leveling)
# --------------------------------------------------------------------------
class AdmissionQueue:
    """Bounded admission in front of a single-server queue.

    ``admit()`` reserves a slot (returns False when the bound is hit —
    caller sheds the request), ``release()`` frees it when the request
    finishes service.  ``depth``/``max_depth``/``shed`` expose the load
    signal the degradation policy and the benchmarks read.  The bound may
    move at runtime (``set_capacity``) — the AIMD controller below drives
    it from the measured service-time distribution.
    """

    def __init__(self, capacity: int, scope: str = "admission") -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.depth = 0
        self.max_depth = 0
        self.admitted = 0
        self.shed = 0
        reg = get_registry()
        self._m_admitted = reg.counter(f"{scope}.admitted")
        self._m_shed = reg.counter(f"{scope}.shed")
        self._g_depth = reg.gauge(f"{scope}.depth")

    def admit(self) -> bool:
        if self.depth >= self.capacity:
            self.shed += 1
            self._m_shed.inc()
            return False
        self.depth += 1
        self.admitted += 1
        self._m_admitted.inc()
        if self.depth > self.max_depth:
            self.max_depth = self.depth
        self._g_depth.set(self.depth)
        return True

    def release(self) -> None:
        assert self.depth > 0, "release without admit"
        self.depth -= 1

    def set_capacity(self, capacity: int) -> None:
        """Move the bound (adaptive control).  In-flight requests above a
        lowered bound drain naturally; only new admissions see the change."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity

    def frac(self) -> float:
        """Current fill fraction — the pressure signal for degradation."""
        return self.depth / self.capacity


class AimdBound:
    """Adaptive admission bound: AIMD around a queueing-delay target.

    A static bound is tuned for one service-time regime; when the measured
    per-request service time drifts (op-mix change, degraded backend), the
    same depth means a very different queueing delay.  This controller
    derives the depth that keeps expected worst-case queue delay near
    ``target_delay_us`` (depth x p50 service time ~= delay, single-server
    queue) from the registry's live service-time histogram, and moves the
    queue's capacity toward it AIMD-style: +1 per tick while below the
    derived bound (gentle probing), multiplicative decrease (x ``beta``)
    when above it (fast backoff when service times inflate).
    """

    def __init__(self, queue: AdmissionQueue, service_hist: Histogram,
                 target_delay_us: float, min_cap: int = 4,
                 max_cap: int = 1024, beta: float = 0.7) -> None:
        self.queue = queue
        self.service_hist = service_hist
        self.target_delay_us = target_delay_us
        self.min_cap = min_cap
        self.max_cap = max_cap
        self.beta = beta
        self.ticks = 0
        self._g_cap = get_registry().gauge("admission.capacity")

    def derived_bound(self) -> Optional[int]:
        if self.service_hist.count < 16:
            return None   # not enough signal yet; hold the current bound
        p50 = self.service_hist.percentile(0.50)
        if p50 <= 0:
            return None
        want = int(self.target_delay_us / p50)
        return max(self.min_cap, min(self.max_cap, want))

    def tick(self) -> int:
        """One control step; returns the (possibly unchanged) capacity."""
        self.ticks += 1
        want = self.derived_bound()
        cap = self.queue.capacity
        if want is not None:
            if cap < want:
                cap += 1                                   # additive increase
            elif cap > want:
                cap = max(want, self.min_cap, int(cap * self.beta))
            if cap != self.queue.capacity:
                self.queue.set_capacity(cap)
        self._g_cap.set(cap)
        return cap


# --------------------------------------------------------------------------
# Per-client throttling
# --------------------------------------------------------------------------
class TokenBucket:
    """Classic token bucket: ``rate`` tokens per time unit, ``burst`` cap.

    Lazy refill — tokens accrue on each ``allow`` call from the elapsed
    time, so idle buckets cost nothing.
    """

    __slots__ = ("rate", "burst", "tokens", "t_last")

    def __init__(self, rate: float, burst: float, now: float = 0.0) -> None:
        self.rate = rate
        self.burst = burst
        self.tokens = burst
        self.t_last = now

    def allow(self, now: float, cost: float = 1.0) -> bool:
        if now > self.t_last:
            self.tokens = min(self.burst, self.tokens + (now - self.t_last) * self.rate)
            self.t_last = now
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False


class ClientThrottle:
    """Per-client token buckets, materialized lazily (an open-loop storm has
    10^5–10^6 client ids; only active ones pay memory)."""

    def __init__(self, rate: float, burst: float) -> None:
        self.rate = rate
        self.burst = burst
        self._buckets: Dict[int, TokenBucket] = {}
        self.throttled = 0

    def allow(self, client_id: int, now: float) -> bool:
        b = self._buckets.get(client_id)
        if b is None:
            b = self._buckets[client_id] = TokenBucket(self.rate, self.burst, now)
        if b.allow(now):
            return True
        self.throttled += 1
        return False


# --------------------------------------------------------------------------
# Circuit breaker (client side, per shard)
# --------------------------------------------------------------------------
class BreakerState(enum.Enum):
    CLOSED = "CLOSED"
    OPEN = "OPEN"
    HALF_OPEN = "HALF_OPEN"


class CircuitBreaker:
    """Trip after ``failure_threshold`` consecutive failures; while OPEN all
    requests fail fast (no network attempt).  After ``reset_timeout`` the
    breaker admits up to ``half_open_probes`` trial requests: one success
    closes it, one failure re-opens it (and restarts the cooldown).
    """

    def __init__(self, failure_threshold: int = 5,
                 reset_timeout: float = 2000.0,
                 half_open_probes: int = 1) -> None:
        self.failure_threshold = failure_threshold
        self.reset_timeout = reset_timeout
        self.half_open_probes = half_open_probes
        self.state = BreakerState.CLOSED
        self.failures = 0
        self.opened_at: Optional[float] = None
        self._probes_out = 0
        self.stats = {"trips": 0, "fast_fails": 0, "probes": 0, "closes": 0}
        reg = get_registry()
        self._m_trips = reg.counter("breaker.trips")
        self._m_closes = reg.counter("breaker.closes")
        self._m_fast_fails = reg.counter("breaker.fast_fails")
        self._m_half_opens = reg.counter("breaker.half_opens")

    def allow(self, now: float) -> bool:
        """May a request be sent now?  (HALF_OPEN admissions count as probes
        until an outcome is recorded.)"""
        if self.state is BreakerState.CLOSED:
            return True
        if self.state is BreakerState.OPEN:
            if now - self.opened_at >= self.reset_timeout:
                self.state = BreakerState.HALF_OPEN
                self._m_half_opens.inc()
                self._probes_out = 0
            else:
                self.stats["fast_fails"] += 1
                self._m_fast_fails.inc()
                return False
        # HALF_OPEN: bounded concurrent probes.
        if self._probes_out < self.half_open_probes:
            self._probes_out += 1
            self.stats["probes"] += 1
            return True
        self.stats["fast_fails"] += 1
        self._m_fast_fails.inc()
        return False

    def record_success(self) -> None:
        if self.state is BreakerState.HALF_OPEN:
            self.stats["closes"] += 1
            self._m_closes.inc()
        self.state = BreakerState.CLOSED
        self.failures = 0
        self._probes_out = 0

    def record_failure(self, now: float) -> None:
        if self.state is BreakerState.HALF_OPEN:
            self._trip(now)
            return
        self.failures += 1
        if self.state is BreakerState.CLOSED and \
                self.failures >= self.failure_threshold:
            self._trip(now)

    def _trip(self, now: float) -> None:
        self.state = BreakerState.OPEN
        self.opened_at = now
        self.failures = 0
        self._probes_out = 0
        self.stats["trips"] += 1
        self._m_trips.inc()


# --------------------------------------------------------------------------
# Graceful degradation
# --------------------------------------------------------------------------
class DegradeLevel(enum.IntEnum):
    NORMAL = 0      # full service
    DEFER_SLOW = 1  # defer batched backup syncs + witness gc (slow path)


def degrade_level(frac: float, level: DegradeLevel,
                  hi: float, lo: float) -> DegradeLevel:
    """Hysteresis thresholding of the admission-fill signal: enter
    DEFER_SLOW at ``hi``, leave it only below ``lo`` (lo < hi), so the
    server does not flap at the boundary."""
    if level is DegradeLevel.NORMAL:
        return DegradeLevel.DEFER_SLOW if frac >= hi else DegradeLevel.NORMAL
    return DegradeLevel.NORMAL if frac < lo else DegradeLevel.DEFER_SLOW


# --------------------------------------------------------------------------
# Armor configuration bundle
# --------------------------------------------------------------------------
@dataclass
class ArmorConfig:
    """One knob bundle for a server's survival kit (sim wiring reads this).

    ``throttle_rate`` is in ops per µs per client (e.g. 0.01 = 10k ops/s);
    rate <= 0 disables the per-client throttle.  ``degrade_hi``/``lo`` are
    admission-fill fractions with hysteresis (see ``degrade_level``).

    ``adaptive`` replaces the static master admission bound with the AIMD
    controller (``AimdBound``) driven by the registry's measured master
    service-time histogram: ``queue_capacity`` becomes the starting point,
    and the bound converges to ~``adaptive_target_delay_us`` of expected
    queueing delay within [``adaptive_min``, ``adaptive_max``], re-derived
    every ``adaptive_interval_ops`` served requests.
    """
    queue_capacity: int = 64
    witness_queue_capacity: int = 128
    throttle_rate: float = 0.0
    throttle_burst: float = 8.0
    degrade_hi: float = 0.75
    degrade_lo: float = 0.40
    adaptive: bool = False
    adaptive_target_delay_us: float = 40.0
    adaptive_min: int = 4
    adaptive_max: int = 256
    adaptive_interval_ops: int = 32

    def make_queue(self) -> AdmissionQueue:
        return AdmissionQueue(self.queue_capacity, scope="admission")

    def make_witness_queue(self) -> AdmissionQueue:
        return AdmissionQueue(self.witness_queue_capacity,
                              scope="admission_witness")

    def make_aimd(self, queue: AdmissionQueue,
                  service_hist: Histogram) -> Optional[AimdBound]:
        if not self.adaptive:
            return None
        return AimdBound(queue, service_hist, self.adaptive_target_delay_us,
                         self.adaptive_min, self.adaptive_max)

    def make_throttle(self) -> Optional[ClientThrottle]:
        if self.throttle_rate <= 0:
            return None
        return ClientThrottle(self.throttle_rate, self.throttle_burst)
