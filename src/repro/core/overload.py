"""Serving-layer survival kit: admission control, throttling, circuit
breaking, and graceful degradation for overloaded CURP servers.

These are the protocol-agnostic policy objects behind the "production
traffic armor" scenarios (repro.sim's open-loop storms, benchmarks/fig_slo):

* ``AdmissionQueue`` — queue-based load leveling in front of a single-server
  node: a bounded count of delivered-but-not-yet-served messages.  Arrivals
  beyond the bound are shed *immediately* (fail fast) instead of joining a
  queue whose wait already exceeds any useful deadline.  The shed reply is
  explicit, so clients back off rather than timing out and retrying into
  the same overload.
* ``TokenBucket`` / ``ClientThrottle`` — per-client rate limiting at the
  server: one misbehaving (or retry-storming) client cannot claim more than
  its provisioned share of admission slots.
* ``CircuitBreaker`` — client-side per-shard failure accounting: trips OPEN
  after consecutive failures (timeouts, NOT_OWNER on a mid-migration slot,
  crashed-master silence), fails fast while OPEN, and re-probes with a
  bounded number of HALF_OPEN trial requests after a cooldown.
* ``DegradeLevel``/``degrade_level`` — graceful degradation policy: under
  pressure the server sheds *slow-path* work first (defer batched backup
  syncs and witness gc), keeping the witness-backed 1-RTT write path alive;
  conflict-path syncs that gate withheld client replies are never deferred.

All times are caller-supplied floats (the discrete-event sim passes
``sim.now`` in µs); nothing here reads a wall clock, so the objects are
deterministic under simulation and trivially unit-testable.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Optional


# --------------------------------------------------------------------------
# Admission control (queue-based load leveling)
# --------------------------------------------------------------------------
class AdmissionQueue:
    """Bounded admission in front of a single-server queue.

    ``admit()`` reserves a slot (returns False when the bound is hit —
    caller sheds the request), ``release()`` frees it when the request
    finishes service.  ``depth``/``max_depth``/``shed`` expose the load
    signal the degradation policy and the benchmarks read.
    """

    def __init__(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.depth = 0
        self.max_depth = 0
        self.admitted = 0
        self.shed = 0

    def admit(self) -> bool:
        if self.depth >= self.capacity:
            self.shed += 1
            return False
        self.depth += 1
        self.admitted += 1
        if self.depth > self.max_depth:
            self.max_depth = self.depth
        return True

    def release(self) -> None:
        assert self.depth > 0, "release without admit"
        self.depth -= 1

    def frac(self) -> float:
        """Current fill fraction — the pressure signal for degradation."""
        return self.depth / self.capacity


# --------------------------------------------------------------------------
# Per-client throttling
# --------------------------------------------------------------------------
class TokenBucket:
    """Classic token bucket: ``rate`` tokens per time unit, ``burst`` cap.

    Lazy refill — tokens accrue on each ``allow`` call from the elapsed
    time, so idle buckets cost nothing.
    """

    __slots__ = ("rate", "burst", "tokens", "t_last")

    def __init__(self, rate: float, burst: float, now: float = 0.0) -> None:
        self.rate = rate
        self.burst = burst
        self.tokens = burst
        self.t_last = now

    def allow(self, now: float, cost: float = 1.0) -> bool:
        if now > self.t_last:
            self.tokens = min(self.burst, self.tokens + (now - self.t_last) * self.rate)
            self.t_last = now
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False


class ClientThrottle:
    """Per-client token buckets, materialized lazily (an open-loop storm has
    10^5–10^6 client ids; only active ones pay memory)."""

    def __init__(self, rate: float, burst: float) -> None:
        self.rate = rate
        self.burst = burst
        self._buckets: Dict[int, TokenBucket] = {}
        self.throttled = 0

    def allow(self, client_id: int, now: float) -> bool:
        b = self._buckets.get(client_id)
        if b is None:
            b = self._buckets[client_id] = TokenBucket(self.rate, self.burst, now)
        if b.allow(now):
            return True
        self.throttled += 1
        return False


# --------------------------------------------------------------------------
# Circuit breaker (client side, per shard)
# --------------------------------------------------------------------------
class BreakerState(enum.Enum):
    CLOSED = "CLOSED"
    OPEN = "OPEN"
    HALF_OPEN = "HALF_OPEN"


class CircuitBreaker:
    """Trip after ``failure_threshold`` consecutive failures; while OPEN all
    requests fail fast (no network attempt).  After ``reset_timeout`` the
    breaker admits up to ``half_open_probes`` trial requests: one success
    closes it, one failure re-opens it (and restarts the cooldown).
    """

    def __init__(self, failure_threshold: int = 5,
                 reset_timeout: float = 2000.0,
                 half_open_probes: int = 1) -> None:
        self.failure_threshold = failure_threshold
        self.reset_timeout = reset_timeout
        self.half_open_probes = half_open_probes
        self.state = BreakerState.CLOSED
        self.failures = 0
        self.opened_at: Optional[float] = None
        self._probes_out = 0
        self.stats = {"trips": 0, "fast_fails": 0, "probes": 0, "closes": 0}

    def allow(self, now: float) -> bool:
        """May a request be sent now?  (HALF_OPEN admissions count as probes
        until an outcome is recorded.)"""
        if self.state is BreakerState.CLOSED:
            return True
        if self.state is BreakerState.OPEN:
            if now - self.opened_at >= self.reset_timeout:
                self.state = BreakerState.HALF_OPEN
                self._probes_out = 0
            else:
                self.stats["fast_fails"] += 1
                return False
        # HALF_OPEN: bounded concurrent probes.
        if self._probes_out < self.half_open_probes:
            self._probes_out += 1
            self.stats["probes"] += 1
            return True
        self.stats["fast_fails"] += 1
        return False

    def record_success(self) -> None:
        if self.state is BreakerState.HALF_OPEN:
            self.stats["closes"] += 1
        self.state = BreakerState.CLOSED
        self.failures = 0
        self._probes_out = 0

    def record_failure(self, now: float) -> None:
        if self.state is BreakerState.HALF_OPEN:
            self._trip(now)
            return
        self.failures += 1
        if self.state is BreakerState.CLOSED and \
                self.failures >= self.failure_threshold:
            self._trip(now)

    def _trip(self, now: float) -> None:
        self.state = BreakerState.OPEN
        self.opened_at = now
        self.failures = 0
        self._probes_out = 0
        self.stats["trips"] += 1


# --------------------------------------------------------------------------
# Graceful degradation
# --------------------------------------------------------------------------
class DegradeLevel(enum.IntEnum):
    NORMAL = 0      # full service
    DEFER_SLOW = 1  # defer batched backup syncs + witness gc (slow path)


def degrade_level(frac: float, level: DegradeLevel,
                  hi: float, lo: float) -> DegradeLevel:
    """Hysteresis thresholding of the admission-fill signal: enter
    DEFER_SLOW at ``hi``, leave it only below ``lo`` (lo < hi), so the
    server does not flap at the boundary."""
    if level is DegradeLevel.NORMAL:
        return DegradeLevel.DEFER_SLOW if frac >= hi else DegradeLevel.NORMAL
    return DegradeLevel.NORMAL if frac < lo else DegradeLevel.DEFER_SLOW


# --------------------------------------------------------------------------
# Armor configuration bundle
# --------------------------------------------------------------------------
@dataclass
class ArmorConfig:
    """One knob bundle for a server's survival kit (sim wiring reads this).

    ``throttle_rate`` is in ops per µs per client (e.g. 0.01 = 10k ops/s);
    rate <= 0 disables the per-client throttle.  ``degrade_hi``/``lo`` are
    admission-fill fractions with hysteresis (see ``degrade_level``).
    """
    queue_capacity: int = 64
    witness_queue_capacity: int = 128
    throttle_rate: float = 0.0
    throttle_burst: float = 8.0
    degrade_hi: float = 0.75
    degrade_lo: float = 0.40

    def make_queue(self) -> AdmissionQueue:
        return AdmissionQueue(self.queue_capacity)

    def make_witness_queue(self) -> AdmissionQueue:
        return AdmissionQueue(self.witness_queue_capacity)

    def make_throttle(self) -> Optional[ClientThrottle]:
        if self.throttle_rate <= 0:
            return None
        return ClientThrottle(self.throttle_rate, self.throttle_burst)
