"""Live reconfiguration: online slot handover between CURP shard groups.

CURP §3.6 covers three reconfigurations: master crash (epoch fence), witness
replacement (WitnessListVersion fence), and DATA MIGRATION — a partition
moves to another master and "requests in witnesses that belong to the
migrated partition are ignored".  This module builds the third one on top of
the slot router (repro.core.shard.SlotRouter): the unit of movement is a
hash SLOT, and a handover walks the same fences the paper uses.

Handover protocol (SlotMigration, donor -> receiver)
----------------------------------------------------
  freeze    The moving slots are registered with the MigrationManager; any
            client op touching them gets a RETRYABLE REDIRECT (SlotMoving)
            *before* any master or witness contact, so it can safely be
            re-issued under a fresh identity once the map settles.  Ops on
            every other slot never leave the 1-RTT fast path.  Undecided
            transaction intents held by the donor are resolved first (their
            key locks must not straddle the handover).
  sync      The donor drains its batched backup syncs: the moving slots'
            unsynced window empties and their witness records are gc'ed, so
            the snapshot below is stable AND f-fault durable.
  transfer  The moved slots' key/value residents plus their live RIFL
            completion records ship to the receiver as ONE ``MIGRATE_IN``
            op through the receiver master's ordinary update path (log entry
            + backup sync), so either side crashing mid-handover loses
            nothing: the receiver re-surfaces absorbed state from its own
            backups, and a resumed handover just re-sends the snapshot
            (idempotent).  Completion records move key-scoped (RAMCloud's
            per-object RIFL), so a client retry across the move dedups at
            the receiver instead of double-applying.
  handover  The commit point.  The donor durably drops the moved keys
            (``MIGRATE_OUT`` log entry), BOTH ends take a ConfigManager
            ``migration_fence`` (epoch + WitnessListVersion bump — in-flight
            records against old witness lists are refused and clients
            refetch, §3.6), and the router's slot map flips.  Witness
            takeover is implicit: new records for the moved slots land at
            the receiver's witnesses; the donor's witnesses hold no moved
            records (gc'ed by the sync stage), and any straggler replayed
            during a later donor recovery is ignored by the ownership filter
            (``Master.owns``), exactly the paper's migrated-partition rule.

Crash recovery is FORWARD-ONLY: the router flip is the single commit point,
every stage before it is idempotent, and ``resume()`` restarts from ``sync``
after a donor or receiver failover.

Hot-shard auto-split
--------------------
``plan_rebalance`` turns per-slot op counters (kept on the shard groups,
fed by the cluster's routing layer) into a greedy move plan: shed the
hottest slots of the hottest shard onto the coldest shards until the load
imbalance drops under a tolerance.  ``ShardedCluster.rebalance`` executes
the plan as live handovers — the attack on the skew80 scaling cap in
benchmarks/fig_scaling.py (see benchmarks/fig_migration.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from .client import ClientSession
from .master import DUP, ERROR, FAST, SYNCED
from .telemetry import get_registry
from .types import Op, OpType


class SlotMoving(Exception):
    """Retryable redirect: the op touches a slot that is mid-handover.

    Raised at the ROUTING stage, before any master or witness saw the op —
    nothing was recorded anywhere under its identity, so the client may
    safely re-issue the op (fresh rpc_id) once the slot map settles.  A
    caller that just allocated the redirected op's identity should release
    it (``session.abandon(op.rpc_id)``) so the RIFL ack frontier keeps
    advancing; ``ShardedCluster.mset``/``txn`` do this automatically for
    identities they allocate.  An op that may ALREADY have reached a master
    (a timeout retry) must instead be re-sent under its ORIGINAL identity
    after the map settles — RIFL (including the migrated completion
    records) dedups it at the new owner.
    """

    def __init__(self, slot: int, src: int, dst: int) -> None:
        super().__init__(
            f"slot {slot} is migrating shard {src} -> {dst}; "
            "refetch the slot map and retry"
        )
        self.slot = slot
        self.src = src
        self.dst = dst


@dataclass
class MigrationReport:
    """Outcome of one donor -> receiver slot handover."""
    slots: Tuple[int, ...]
    src: int
    dst: int
    keys_moved: int
    rifl_moved: int          # completion records shipped with the data
    txn_resolved: int        # donor intents decided at freeze
    src_epoch: int
    dst_epoch: int
    src_wlv: int
    dst_wlv: int
    resumed: int = 0         # crash-resumes survived mid-handover


class SlotMigration:
    """One slot-set handover, driven in idempotent stages (module docstring).

    ``step()`` advances one stage (benchmarks interleave client traffic
    between steps); ``run()`` drives to completion; ``resume()`` restarts
    from ``sync`` after a donor/receiver crash — safe because the router
    flip in ``handover`` is the only non-idempotent effect and it is the
    last one.
    """

    STAGES = ("freeze", "sync", "transfer", "handover", "done")

    def __init__(self, cluster, slots: Sequence[int], src: int,
                 dst: int) -> None:
        self.cluster = cluster
        self.slots = tuple(sorted(set(slots)))
        self._slot_set = frozenset(self.slots)
        self.src = src
        self.dst = dst
        self.stage = "freeze"
        self.keys_moved = 0
        self.rifl_moved = 0
        self.txn_resolved = 0
        self.resumed = 0

    # ------------------------------------------------------------- driving
    def step(self) -> str:
        """Run the next stage; returns the stage now pending (or 'done')."""
        stage = self.stage
        t0 = time.perf_counter()
        if stage == "freeze":
            self._freeze()
            self.stage = "sync"
        elif stage == "sync":
            self._sync()
            self.stage = "transfer"
        elif stage == "transfer":
            self._transfer()
            self.stage = "handover"
        elif stage == "handover":
            self._handover()
            self.stage = "done"
        if stage != "done":
            reg = get_registry()
            reg.histogram(f"migration.stage_us.{stage}").record(
                (time.perf_counter() - t0) * 1e6
            )
            reg.counter("migration.stages").inc()
        return self.stage

    def run(self) -> MigrationReport:
        while self.stage != "done":
            self.step()
        return self.report()

    def resume(self) -> None:
        """Restart after a donor or receiver failover mid-handover.  The
        recovered master rebuilt all synced state from its backups (incl.
        any absorbed MIGRATE_IN), so redoing sync -> transfer -> handover is
        safe and re-sends nothing the receiver can't dedup."""
        if self.stage == "done":
            return
        self.resumed += 1
        self.stage = "sync"

    def report(self) -> MigrationReport:
        src_cfg = self.cluster.config.fetch(self.src)
        dst_cfg = self.cluster.config.fetch(self.dst)
        return MigrationReport(
            slots=self.slots, src=self.src, dst=self.dst,
            keys_moved=self.keys_moved, rifl_moved=self.rifl_moved,
            txn_resolved=self.txn_resolved,
            src_epoch=src_cfg.epoch, dst_epoch=dst_cfg.epoch,
            src_wlv=src_cfg.witness_list_version,
            dst_wlv=dst_cfg.witness_list_version,
            resumed=self.resumed,
        )

    # -------------------------------------------------------------- stages
    def _freeze(self) -> None:
        """Decide every undecided intent the donor holds: an intent lock on
        a moving key cannot straddle the handover (the intent's 2PC legs are
        pinned to the pre-move owner)."""
        from .txn import resolve_txn

        donor = self.cluster.shards[self.src]
        for _txn_id, (spec, _part) in list(
            donor.master.store.txn_intents().items()
        ):
            resolve_txn(self.cluster, spec)
            self.txn_resolved += 1

    def _sync(self) -> None:
        self.cluster.shards[self.src].sync_now()

    def _transfer(self) -> None:
        """Ship the moved slots' residents + live RIFL completions to the
        receiver as one MIGRATE_IN log entry, then make it backup-durable."""
        cluster = self.cluster
        donor = cluster.shards[self.src]
        recv = cluster.shards[self.dst]
        slot_set = self._slot_set
        router = cluster.router

        store = donor.master.store
        kvs = tuple(
            (k, store.get(k)) for k in store.keys()
            if router.slot_of(k) in slot_set
        )
        # Completion records ride with the data: every log entry wholly
        # inside the moved slots whose completion is still live (un-acked)
        # moves, keyed (rpc_id, key_hashes) — see Master.migrated_rifl.
        records: Dict[Tuple, Tuple] = {}
        for e in donor.master.log:
            op = e.op
            if op.op_type in (OpType.MIGRATE_IN, OpType.MIGRATE_OUT):
                continue
            if not op.keys or not all(
                router.slot_of(k) in slot_set for k in op.keys
            ):
                continue
            rec = donor.master.rifl.check_duplicate(op.rpc_id)
            if rec is None:
                continue
            # Live records migrate verbatim; already-ACKED ops migrate the
            # synthetic ignore-as-duplicate marker (result None) the donor
            # itself would serve, so retry behavior is identical either way.
            records[(op.rpc_id, op.key_hashes())] = (
                op.rpc_id, op.key_hashes(), rec.result
            )
        # Chain migrations: completions that arrived here WITH an earlier
        # handover forward onward with the slots they cover.
        for (rpc_id, khs), result in donor.master.migrated_rifl.items():
            if all(router.slot_of_hash(kh) in slot_set for kh in khs):
                records[(rpc_id, khs)] = (rpc_id, khs, result)

        self.keys_moved = len(kvs)
        self.rifl_moved = len(records)
        if not kvs and not records:
            return
        op = Op(
            OpType.MIGRATE_IN,
            tuple(k for k, _ in kvs),
            (kvs, tuple(records.values())),
            cluster.migration.session.next_rpc_id(),
        )
        cfg = cluster.config.fetch(self.dst)
        verdict, result = recv.master.handle_update(
            op, cfg.witness_list_version, (), 0.0
        )
        assert verdict in (FAST, SYNCED, DUP), (verdict, result.error)
        recv.sync_now()  # the absorb must be f-fault durable pre-commit

    def _handover(self) -> None:
        """The commit point: donor drops, both ends fence, the map flips."""
        cluster = self.cluster
        donor = cluster.shards[self.src]
        recv = cluster.shards[self.dst]
        slot_set = self._slot_set
        router = cluster.router

        # 1. Donor durably forgets the moved keys (its backups replay the
        #    drop, so a later donor failover cannot resurrect them).
        moved = tuple(
            k for k in donor.master.store.keys()
            if router.slot_of(k) in slot_set
        )
        if moved:
            cfg = cluster.config.fetch(self.src)
            op = Op(OpType.MIGRATE_OUT, moved, (),
                    cluster.migration.session.next_rpc_id())
            verdict, result = donor.master.handle_update(
                op, cfg.witness_list_version, (), 0.0
            )
            assert verdict != ERROR, result.error
            donor.sync_now()

        # 2. Fence both ends (§3.6): epoch + WitnessListVersion bumps pushed
        #    into the live masters and their backups.  In-flight records
        #    against the pre-handover witness lists are refused at the
        #    masters and the clients refetch.
        jr = cluster.migration.journal
        for sid, group in ((self.src, donor), (self.dst, recv)):
            cfg = cluster.config.migration_fence(sid)
            group.master.epoch = cfg.epoch
            group.master.witness_list_version = cfg.witness_list_version
            for b in group.backups:
                b.set_epoch(cfg.epoch)
            if jr is not None:
                jr.emit("fence", actor="migration", shard=sid,
                        epoch=cfg.epoch, wlv=cfg.witness_list_version,
                        reason="migration")

        # 3. Commit: flip the slot map; new ops route to (and record at) the
        #    receiver and its witnesses.
        router.assign(self.slots, self.dst)
        if jr is not None:
            jr.emit("handover", actor="migration", slots=self.slots,
                    src=self.src, dst=self.dst)
        cluster.migration.finish(self)


class MigrationManager:
    """The cluster's live-reconfiguration control plane.

    Owns the set of in-flight handovers (the routing layer consults it for
    redirects), the migration RPC identity space (MIGRATE_IN/OUT transfer
    ops carry rpc_ids from a reserved internal client), and the completed-
    handover history.
    """

    def __init__(self, cluster) -> None:
        self.cluster = cluster
        self.session = ClientSession(client_id=cluster._node_id())
        self.active: Dict[int, SlotMigration] = {}   # moving slot -> handover
        self.history: List[MigrationReport] = []
        # Optional black-box journal: freeze/fence/handover events feed the
        # watchdog's single-owner-per-slot monitor.
        self.journal = None

    # ------------------------------------------------------------ redirects
    def check_slots(self, slots) -> None:
        """Raise the retryable redirect if any slot is mid-handover."""
        for s in slots:
            mig = self.active.get(s)
            if mig is not None:
                get_registry().counter("migration.redirects").inc()
                raise SlotMoving(s, mig.src, mig.dst)

    def check_keys(self, keys) -> None:
        self.check_slots(self.cluster.router.slot_of(k) for k in keys)

    # -------------------------------------------------------------- control
    def start(self, slots: Sequence[int], dst: int) -> List[SlotMigration]:
        """Register handovers moving ``slots`` to shard ``dst`` (one
        SlotMigration per donor), freezing the slots immediately.  Returns
        the handles; drive them with ``step()``/``run()``."""
        router = self.cluster.router
        group = self.cluster.shards[dst]
        if getattr(group, "retired", False):
            raise ValueError(f"shard {dst} is retired")
        by_src: Dict[int, List[int]] = {}
        for s in set(slots):
            if not 0 <= s < router.n_slots:
                raise ValueError(f"slot {s} out of range")
            if s in self.active:
                raise ValueError(f"slot {s} already migrating")
            src = router.slot_map[s]
            if src == dst:
                continue
            by_src.setdefault(src, []).append(s)
        migs = [
            SlotMigration(self.cluster, sl, src, dst)
            for src, sl in sorted(by_src.items())
        ]
        for m in migs:
            for s in m.slots:
                self.active[s] = m
            if self.journal is not None:
                self.journal.emit("freeze", actor="migration", slots=m.slots,
                                  src=m.src, dst=m.dst)
        return migs

    def migrate(self, slots: Sequence[int], dst: int) -> List[MigrationReport]:
        """Run the full handover(s) to completion (no traffic interleave)."""
        return [m.run() for m in self.start(slots, dst)]

    def finish(self, mig: SlotMigration) -> None:
        for s in mig.slots:
            self.active.pop(s, None)
        self.history.append(mig.report())
        get_registry().counter("migration.handovers").inc()


def plan_rebalance(
    slot_loads: Sequence[int],
    slot_map: Sequence[int],
    shard_ids: Sequence[int],
    max_moves: int = 64,
    tolerance: float = 1.1,
) -> Dict[int, List[int]]:
    """Greedy hot-slot shedding: {dst_shard: [slots to move there]}.

    Repeatedly take the hottest shard's hottest slot and hand it to the
    coldest shard, until the hottest shard is within ``tolerance`` of the
    mean load, every shard keeps at least one slot, or ``max_moves`` is
    spent.  A move must strictly reduce the donor/receiver gap (the slot
    fits under the donor's load at the receiver), which guarantees
    termination without oscillation.
    """
    shard_ids = list(shard_ids)
    if len(shard_ids) < 2:
        return {}
    loads = {sid: 0 for sid in shard_ids}
    owner_slots: Dict[int, List[int]] = {sid: [] for sid in shard_ids}
    for slot, owner in enumerate(slot_map):
        if owner in loads:
            loads[owner] += slot_loads[slot]
            owner_slots[owner].append(slot)
    total = sum(loads.values())
    if total == 0:
        return {}
    target = total / len(shard_ids)
    for slots in owner_slots.values():
        slots.sort(key=lambda s: -slot_loads[s])   # hottest first

    # A slot may be shed more than once while planning (to the coldest
    # shard, which later becomes hottest); only its FINAL owner is emitted,
    # so each slot pays at most one handover and the executed placement is
    # exactly the planned one regardless of migration order.
    final: Dict[int, int] = {}
    for _ in range(max_moves):
        hot = max(shard_ids, key=lambda sid: loads[sid])
        cold = min(shard_ids, key=lambda sid: loads[sid])
        if loads[hot] <= tolerance * target or hot == cold:
            break
        candidates = [
            s for s in owner_slots[hot]
            if slot_loads[s] > 0
            and loads[cold] + slot_loads[s] < loads[hot]
        ]
        if not candidates or len(owner_slots[hot]) <= 1:
            break
        slot = candidates[0]                        # hottest movable slot
        owner_slots[hot].remove(slot)
        owner_slots[cold].append(slot)
        loads[hot] -= slot_loads[slot]
        loads[cold] += slot_loads[slot]
        if slot_map[slot] == cold:
            final.pop(slot, None)                   # shed back to its owner
        else:
            final[slot] = cold
    moves: Dict[int, List[int]] = {}
    for slot, dst in sorted(final.items()):
        moves.setdefault(dst, []).append(slot)
    return moves
