"""CURP on consensus (§A.2): 1-RTT updates for strong-leader consensus.

Model: 2f+1 replicas; each replica embeds a witness component.  The leader
speculatively executes and replies before committing to a majority; a client
completes in 1 RTT iff a SUPERQUORUM of f + ceil(f/2) + 1 witnesses accepted
its record.  On leader change, the new leader gathers witness data from any
f+1 replicas and replays exactly the requests recorded by a majority of that
quorum (>= ceil(f/2)+1): the superquorum write-side guarantees every completed
-but-uncommitted op appears that often, and no two non-commutative ops both
can (each witness enforces commutativity independently).

This is a protocol study (unit-tested for the quorum math + replay safety),
not a full Raft: log replication/commit is abstracted to direct calls, like
the rest of repro.core, while the CURP-specific logic is complete.
"""
from __future__ import annotations

import math
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .rifl import RiflTable
from .store import KVStore
from .types import Op, RecordStatus
from .witness import Witness


def superquorum(f: int) -> int:
    return f + math.ceil(f / 2) + 1


def replay_threshold(f: int) -> int:
    return math.ceil(f / 2) + 1


@dataclass
class Replica:
    replica_id: int
    term: int = 0
    log: List[Tuple[Op, object]] = field(default_factory=list)
    commit_index: int = 0
    witness: Witness = field(default_factory=lambda: Witness(256, 4))

    def __post_init__(self) -> None:
        self.witness.start(self.replica_id)


class ConsensusCluster:
    """2f+1 replicas, one strong leader, CURP witnesses embedded."""

    def __init__(self, f: int = 2, commit_batch: int = 16) -> None:
        self.f = f
        self.n = 2 * f + 1
        self.commit_batch = commit_batch
        self.replicas = [Replica(i) for i in range(self.n)]
        self.leader_idx = 0
        self.term = 0
        self.store = KVStore()           # leader's speculative state machine
        self.rifl = RiflTable()
        self.crashed: set[int] = set()

    @property
    def leader(self) -> Replica:
        return self.replicas[self.leader_idx]

    def live(self) -> List[Replica]:
        return [r for r in self.replicas if r.replica_id not in self.crashed]

    # ------------------------------------------------------------- client path
    def update(self, op: Op) -> Tuple[object, bool]:
        """Returns (result, completed_in_1rtt).

        The leader speculatively executes; the client records to all live
        witnesses with the current term (§A.2 zombie-leader fence) and
        completes in 1 RTT on a superquorum of accepts.  Otherwise the client
        asks the leader to commit to a majority first (2 RTTs).
        """
        dup = self.rifl.check_duplicate(op.rpc_id)
        if dup is not None:
            return dup.result, False
        result = self.store.execute(op)
        self.rifl.record_completion(op.rpc_id, result, synced=False)
        self.leader.log.append((op, result))

        accepts = 0
        for r in self.live():
            # Term fence: witnesses embedded in replicas reject stale terms.
            if r.term > self.term:
                continue
            if (
                r.witness.record(r.replica_id, op.key_hashes(), op.rpc_id, op)
                is RecordStatus.ACCEPTED
            ):
                accepts += 1
        if accepts >= superquorum(self.f):
            if len(self.leader.log) - self.leader.commit_index >= self.commit_batch:
                self.commit()
            return result, True
        # Slow path: commit through a majority before replying.
        self.commit()
        return result, False

    # ----------------------------------------------------------------- commit
    def commit(self) -> None:
        """Replicate the leader log to a majority; advance commit_index; gc."""
        through = len(self.leader.log)
        acked = 1
        for r in self.live():
            if r is self.leader:
                continue
            r.log = list(self.leader.log)
            acked += 1
        if acked >= self.f + 1:
            newly = self.leader.log[self.leader.commit_index:through]
            for r in self.live():
                r.commit_index = max(r.commit_index, through)
            gc_entries = tuple(
                (kh, op.rpc_id) for op, _ in newly for kh in op.key_hashes()
            )
            self.rifl.mark_synced_through(op.rpc_id for op, _ in newly)
            for r in self.live():
                r.witness.gc(gc_entries)

    # ---------------------------------------------------------- leader change
    def crash(self, replica_id: int) -> None:
        self.crashed.add(replica_id)

    def change_leader(self) -> Dict[str, int]:
        """Elect the live replica with the longest committed log; replay
        witness records that appear >= ceil(f/2)+1 times in a quorum of f+1
        witnesses (§A.2)."""
        live = self.live()
        assert len(live) >= self.f + 1, "need a quorum to elect"
        self.term += 1
        new_leader = max(live, key=lambda r: r.commit_index)
        self.leader_idx = self.replicas.index(new_leader)

        # Rebuild state machine from the committed log only (speculative
        # suffix of a crashed old leader is NOT trusted).
        self.store = KVStore()
        self.rifl = RiflTable()
        committed = new_leader.log[: new_leader.commit_index]
        for op, result in committed:
            self.store.execute(op)
            self.rifl.record_completion(op.rpc_id, result, synced=True)
        new_leader.log = list(committed)
        new_leader.commit_index = len(committed)

        # Gather witness data from a quorum of f+1 live replicas.
        quorum = live[: self.f + 1]
        counter: Counter = Counter()
        requests: Dict = {}
        for r in quorum:
            for op in r.witness.get_recovery_data(r.replica_id):
                counter[op.rpc_id] += 1
                requests[op.rpc_id] = op
        threshold = replay_threshold(self.f)
        replayed = 0
        for rpc_id, cnt in counter.items():
            if cnt >= threshold and self.rifl.check_duplicate(rpc_id) is None:
                op = requests[rpc_id]
                result = self.store.execute(op)
                self.rifl.record_completion(op.rpc_id, result, synced=False)
                new_leader.log.append((op, result))
                replayed += 1
        self.commit()

        # Fresh witnesses for the new term.
        for r in live:
            r.term = self.term
            r.witness = Witness(r.witness.n_sets, r.witness.n_ways)
            r.witness.start(r.replica_id)
        return {"replayed": replayed, "term": self.term,
                "committed": new_leader.commit_index}
