"""CURP master (§3.2.3, §4.3, §4.4).

The master executes all updates, but — unlike classic primary-backup — replies
*before* replicating to backups ("speculative execution"), as long as the new
operation commutes with every *unsynced* operation.  Backup syncs are batched
(§4.4, batch of up to ``sync_batch`` ops) and run asynchronously.

The master is transport-agnostic: it decides WHAT must happen
(fast-respond / sync-before-respond / duplicate / error) and exposes
``begin_sync``/``complete_sync`` for the harness (simulator or local runner)
that owns actual RPC delivery.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .backup import LogEntry
from .merge import conflicts
from .rifl import RiflTable
from .store import KVStore
from .telemetry import get_registry
from .types import TXN_OPS, BackupSyncReq, ExecResult, Op, OpType, RpcId

# Verdicts for an incoming update.
FAST = "fast"            # executed, reply immediately (1 RTT path)
SYNCED = "synced"        # executed + must sync before replying (conflict path)
DUP = "dup"              # RIFL duplicate, reply with saved result
ERROR = "error"


@dataclass
class PendingSync:
    """An in-flight batched backup sync."""
    through_index: int
    req: BackupSyncReq
    acks: int = 0


class Master:
    def __init__(
        self,
        master_id: int,
        epoch: int = 0,
        sync_batch: int = 50,
        hot_key_sync: bool = True,
        hot_key_window: float = 0.0,
    ) -> None:
        self.master_id = master_id
        self.epoch = epoch
        self.sync_batch = sync_batch
        self.hot_key_sync = hot_key_sync
        # "updated recently" horizon for the §4.4 preemptive-sync heuristic:
        # an update to a key whose previous update is still unsynced hints the
        # key is hot; sync right after responding.
        self.hot_key_window = hot_key_window

        self.store = KVStore()
        self.rifl = RiflTable()
        self.log: List[LogEntry] = []
        self.synced_index = 0                 # log[:synced_index] is on backups
        self.witness_list_version = 0
        # The §3.2.3 unsynced window, merge-lattice aware: keyhash -> the
        # {merge-class: refcount} map of unsynced (hash, class) pairs from
        # Op.hash_classes().  A new op commutes iff none of its pairs
        # CONFLICTS (repro.core.merge) with a held class at the same hash —
        # e.g. INCR rides the fast path over unsynced INCRs of the same key.
        self._unsynced_keyhash: Dict[int, Dict[int, int]] = {}
        self.sync_in_progress: Optional[PendingSync] = None
        self.want_sync: bool = False          # sync requested (batch full / conflict)
        self.owned_partition = None           # optional key filter (migration §3.6)
        # RIFL completion records that arrived WITH migrated data (§3.6 slot
        # handover, RAMCloud-style per-object RIFL): keyed by (rpc_id,
        # key_hashes) so a moved op's retry dedups here while this master's
        # native records stay untouched.  Truncated by client acks like the
        # native table: a piggybacked (client, first_incomplete) frontier
        # proves the client saw results for every seq below it, so those
        # moved completions can never be retried again and are dropped
        # (see _gc_migrated).
        self.migrated_rifl: Dict[Tuple[RpcId, Tuple[int, ...]], Any] = {}
        # Per-client ack frontier already swept over migrated_rifl, so the
        # overlay scan runs only when a client's frontier advances — steady
        # traffic with no new acks pays a dict lookup, not a table walk.
        self._migrated_ack_seen: Dict[int, int] = {}
        self.stats = {
            "fast": 0, "conflict_syncs": 0, "dups": 0, "batch_syncs": 0,
            "reads_fast": 0, "reads_blocked": 0, "hot_key_syncs": 0,
            "txn_prepares": 0, "txn_commits": 0, "txn_aborts": 0,
            "txn_vote_no": 0, "migrated_in_keys": 0, "migrated_out_keys": 0,
            "migrated_rifl_gcd": 0,
        }
        # Optional black-box journal (repro.core.journal.EventJournal): the
        # watchdog attaches one; hooks below are attribute-load + None-check
        # when absent, so they stay in the hot path permanently.
        self.journal = None
        self.journal_actor = f"m{master_id}"
        reg = get_registry()
        self._m_fast = reg.counter("master.fast")
        self._m_conflict_syncs = reg.counter("master.conflict_syncs")
        self._m_dups = reg.counter("master.dups")
        self._m_batch_syncs = reg.counter("master.batch_syncs")
        self._m_hot_key_syncs = reg.counter("master.hot_key_syncs")
        self._h_window = reg.histogram("master.unsynced_window")
        self._h_sync_batch = reg.histogram("master.sync_batch_ops")

    # ------------------------------------------------------------------ utils
    @property
    def unsynced_count(self) -> int:
        return len(self.log) - self.synced_index

    def _commutes(self, op: Op) -> bool:
        for kh, cls in op.hash_classes():
            held = self._unsynced_keyhash.get(kh)
            if not held:
                continue
            for held_cls in held:
                if conflicts(held_cls, cls):
                    return False
        return True

    def _window_add(self, op: Op) -> None:
        for kh, cls in op.hash_classes():
            per_cls = self._unsynced_keyhash.setdefault(kh, {})
            per_cls[cls] = per_cls.get(cls, 0) + 1

    def _window_remove(self, op: Op) -> None:
        for kh, cls in op.hash_classes():
            per_cls = self._unsynced_keyhash.get(kh)
            if per_cls is None:
                continue
            cnt = per_cls.get(cls, 0) - 1
            if cnt <= 0:
                per_cls.pop(cls, None)
                if not per_cls:
                    self._unsynced_keyhash.pop(kh, None)
            else:
                per_cls[cls] = cnt

    def _jexec(self, op: Op, verdict: str, checked: bool,
               txn: Optional[Tuple[int, int]] = None) -> None:
        """Journal one executed-and-logged op (watchdog sensor; see
        repro.core.journal).  ``checked`` marks verdicts subject to the
        fast⇒commutes invariant (MIGRATE_IN and txn decide legs reply FAST
        by design without a window check, so the monitor must not judge
        them); ``index`` is the op's 1-based log position, the unit the
        sync events' ``through`` frontier is expressed in."""
        jr = self.journal
        if jr is None:
            return
        jr.emit(
            "execute", actor=self.journal_actor, rpc=op.rpc_id,
            mid=self.master_id, op=op.op_type.name, verdict=verdict,
            checked=checked, index=len(self.log),
            pairs=op.hash_classes(),
            frontier=self.rifl.acked_frontier(op.rpc_id[0]),
            epoch=self.epoch, txn=txn,
        )

    def owns(self, op: Op) -> bool:
        if op.op_type is OpType.MIGRATE_IN:
            # The handover mechanism itself: absorbs keys the routing table
            # does not map here YET (the map flips only after the transfer
            # is durable), so it must bypass the ownership filter.
            return True
        if self.owned_partition is None:
            return True
        return all(self.owned_partition(k) for k in op.keys)

    # --------------------------------------------------------------- updates
    def handle_update(
        self,
        op: Op,
        witness_list_version: int,
        client_acks: Sequence[Tuple[int, int]] = (),
        now: float = 0.0,
        commutes: Optional[bool] = None,
    ) -> Tuple[str, ExecResult]:
        """Execute an update; classify the reply path.

        Returns (verdict, result).  ``SYNCED`` means the harness must complete
        a backup sync through this op before the reply is released; the result
        carries synced=True so the client completes without witness accepts
        (§3.2.3 "tags its result as synced").

        ``commutes`` optionally overrides the host window lookup with a
        commutativity verdict already computed elsewhere — the fused batch
        driver passes the device ring buffer's conflict bit so the host
        ``_unsynced_keyhash`` dict is never consulted on the hot path.
        """
        if witness_list_version != self.witness_list_version:
            # §3.6: stale witness list — client must refetch and retry, else
            # its witness records would land on decommissioned witnesses.
            return ERROR, ExecResult(None, synced=False, ok=False,
                                     error="WRONG_WITNESS_VERSION")
        if not self.owns(op):
            return ERROR, ExecResult(None, synced=False, ok=False,
                                     error="NOT_OWNER")

        self.rifl.apply_client_acks(client_acks)
        if self.migrated_rifl and client_acks:
            self._gc_migrated(client_acks)
        # §3.6 slot handover: a retry of an op that completed on the DONOR
        # before its slot moved here dedups against the migrated completion
        # records (checked first and key-scoped: this master's own records
        # can never be confused with a moved op's).  Membership test, not a
        # get-vs-None: already-ACKED ops migrate with result None (the
        # ignore-as-duplicate marker) and must still dedup, never re-execute.
        mig_key = (op.rpc_id, op.key_hashes())
        if mig_key in self.migrated_rifl:
            self.stats["dups"] += 1
            self._m_dups.inc()
            return DUP, ExecResult(self.migrated_rifl[mig_key], synced=True)
        dup = self.rifl.check_duplicate(op.rpc_id)
        if dup is not None:
            self.stats["dups"] += 1
            self._m_dups.inc()
            return DUP, ExecResult(dup.result, synced=dup.synced)

        if op.op_type in TXN_OPS:
            return self._handle_txn(op, now)
        if op.op_type is OpType.MIGRATE_IN:
            # Receiver side of a slot handover: absorb the moved snapshot +
            # completion records as ONE ordinary log entry, so backup syncs
            # make the transfer durable and a post-crash restore replays it.
            result = self.store.execute(op, now)
            self._install_migrated(op)
            self._log_txn(op, result)
            self.stats["migrated_in_keys"] += len(op.keys)
            self.want_sync = True
            self._jexec(op, FAST, checked=False)
            return FAST, ExecResult(result, synced=False)
        # Keys under an undecided transaction intent cannot be executed:
        # syncing doesn't resolve the intent, so this is not the §3.2.3
        # conflict path — the caller must resolve the transaction (or wait
        # for its coordinator) and retry.  ExecResult.value carries the
        # blocking TxnSpec for exactly that.
        blocking = self.store.txn_lock_conflict(op.keys)
        if blocking is not None:
            return ERROR, ExecResult(blocking, synced=False, ok=False,
                                     error="TXN_PENDING")

        if commutes is None:
            commutes = self._commutes(op)
        # §4.4 hot-key heuristic: was any touched key updated "recently"
        # (within hot_key_window) before this op?  If so it will likely be
        # updated again soon — sync preemptively after responding.
        hot = False
        if self.hot_key_sync and self.hot_key_window > 0:
            for k in op.keys:
                prev = self.store.last_update_time(k)
                if prev is not None and (now - prev) <= self.hot_key_window:
                    hot = True
                    break

        result = self.store.execute(op, now)
        self.rifl.record_completion(op.rpc_id, result, synced=False)
        self.log.append(LogEntry(op, result))
        self._window_add(op)
        self._h_window.record(self.unsynced_count)
        if op.op_type is OpType.MIGRATE_OUT:
            self.stats["migrated_out_keys"] += len(op.keys)

        if not commutes:
            # §3.2.3: must sync (through this op) before externalizing result.
            self.stats["conflict_syncs"] += 1
            self._m_conflict_syncs.inc()
            self.want_sync = True
            self._jexec(op, SYNCED, checked=True)
            return SYNCED, ExecResult(result, synced=True)

        self.stats["fast"] += 1
        self._m_fast.inc()
        self._jexec(op, FAST, checked=True)
        if self.unsynced_count >= self.sync_batch:
            self.want_sync = True
        if hot:
            # §4.4 heuristic: recently-updated key updated again — sync
            # preemptively (after responding) so future ops don't block.
            self.stats["hot_key_syncs"] += 1
            self._m_hot_key_syncs.inc()
            self.want_sync = True
        return FAST, ExecResult(result, synced=False)

    # ----------------------------------------------- migration (migration.py)
    def _gc_migrated(self, client_acks: Sequence[Tuple[int, int]]) -> None:
        """Ack-driven gc of the migrated-completion overlay: a client ack
        frontier (client_id, first_incomplete) proves every seq below it has
        been seen by the client, so the retry window for those moved ops is
        closed — drop their completion records.  Mirrors the native table's
        apply_client_acks sweep, which cannot see this overlay (its entries
        are keyed (rpc_id, key_hashes), not rpc_id)."""
        for cid, first in client_acks:
            if self._migrated_ack_seen.get(cid, 0) >= first:
                continue
            self._migrated_ack_seen[cid] = first
            dead = [k for k in self.migrated_rifl
                    if k[0][0] == cid and k[0][1] < first]
            for k in dead:
                del self.migrated_rifl[k]
            self.stats["migrated_rifl_gcd"] += len(dead)

    def _install_migrated(self, op: Op) -> None:
        """Install the RIFL completion records riding a MIGRATE_IN op (the
        moved ops' exactly-once identities; see handle_update's dedup)."""
        _kvs, records = op.args
        for rpc_id, key_hashes, result in records:
            if self._migrated_ack_seen.get(rpc_id[0], 0) > rpc_id[1]:
                # Already below this client's acked frontier: the client can
                # never retry it, so don't resurrect the record.
                continue
            self.migrated_rifl[(rpc_id, tuple(key_hashes))] = result

    # --------------------------------------------------- transactions (txn.py)
    def _log_txn(self, op: Op, result) -> None:
        """Shared tail of the txn-op paths: RIFL completion + log entry +
        unsynced-window refcounts (symmetric with complete_sync's walk)."""
        self.rifl.record_completion(op.rpc_id, result, synced=False)
        self.log.append(LogEntry(op, result))
        self._window_add(op)

    def _handle_txn(self, op: Op, now: float) -> Tuple[str, ExecResult]:
        """PREPARE / COMMIT / ABORT legs of the 2PC (repro.core.txn).

        PREPARE follows the regular speculative-update rules (commutativity
        vs the unsynced window decides fast vs synced) plus two vote-NO
        gates: a foreign intent lock on any key, or an existing decision
        tombstone under this leg's decide_rpc (installed by crash
        resolution — refusing the straggler prepare closes the classic 2PC
        prepare/resolve race).  COMMIT/ABORT apply immediately and reply
        FAST without witness records or a pre-reply sync: the decision is a
        deterministic function of durable prepare state, so recovery
        re-derives it instead of needing it pre-logged.
        """
        if op.op_type is OpType.TXN_PREPARE:
            spec, shard_id = op.args
            part = spec.part_on(shard_id)
            dec = self.rifl.check_duplicate(part.decide_rpc)
            if dec is not None:
                self.stats["txn_vote_no"] += 1
                return ERROR, ExecResult(dec.result, synced=False, ok=False,
                                         error="TXN_DECIDED")
            blocking = self.store.txn_lock_conflict(op.keys, spec.txn_id)
            if blocking is not None:
                self.stats["txn_vote_no"] += 1
                return ERROR, ExecResult(blocking, synced=False, ok=False,
                                         error="TXN_LOCKED")
            commutes = self._commutes(op)
            result = self.store.execute(op, now)
            self._log_txn(op, result)
            self.stats["txn_prepares"] += 1
            if not commutes:
                self.stats["conflict_syncs"] += 1
                self.want_sync = True
                self._jexec(op, SYNCED, checked=True, txn=spec.txn_id)
                return SYNCED, ExecResult(result, synced=True)
            self.stats["fast"] += 1
            self._jexec(op, FAST, checked=True, txn=spec.txn_id)
            if self.unsynced_count >= self.sync_batch:
                self.want_sync = True
            return FAST, ExecResult(result, synced=False)

        result = self.store.execute(op, now)
        self._log_txn(op, result)
        if op.op_type is OpType.TXN_COMMIT:
            self.stats["txn_commits"] += 1
        else:
            self.stats["txn_aborts"] += 1
        # Keep decision windows short: the intent's witness records stay
        # live until the prepare syncs, so nudge the batched sync along.
        self.want_sync = True
        self._jexec(op, FAST, checked=False, txn=op.args[0].txn_id)
        return FAST, ExecResult(result, synced=False)

    # ----------------------------------------------------------------- reads
    def handle_read(self, op: Op, now: float = 0.0) -> Tuple[str, ExecResult]:
        """Reads of unsynced values must sync first (§3.2.3 / §A.1)."""
        if not self.owns(op):
            return ERROR, ExecResult(None, synced=False, ok=False,
                                     error="NOT_OWNER")
        blocking = self.store.txn_lock_conflict(op.keys)
        if blocking is not None:
            # An undecided intent covers this key: the read cannot be
            # ordered until the transaction resolves (same rule as updates).
            return ERROR, ExecResult(blocking, synced=False, ok=False,
                                     error="TXN_PENDING")
        value = self.store.execute(op, now)
        if self._commutes(op):
            self.stats["reads_fast"] += 1
            return FAST, ExecResult(value, synced=False)
        self.stats["reads_blocked"] += 1
        self.want_sync = True
        return SYNCED, ExecResult(value, synced=True)

    # ------------------------------------------------------------ sync plumbing
    def begin_sync(self) -> Optional[BackupSyncReq]:
        """Start one batched backup sync if needed (one outstanding at a time,
        like RAMCloud).  Returns the request the harness should fan out to all
        backups, or None."""
        if self.sync_in_progress is not None:
            return None
        if not self.want_sync and self.unsynced_count == 0:
            return None
        through = len(self.log)
        if through == self.synced_index:
            self.want_sync = False
            return None
        req = BackupSyncReq(
            master_id=self.master_id,
            epoch=self.epoch,
            from_index=self.synced_index,
            entries=tuple(
                (e.op, e.result) for e in self.log[self.synced_index:through]
            ),
        )
        self.sync_in_progress = PendingSync(through_index=through, req=req)
        self.want_sync = False
        self._h_sync_batch.record(len(req.entries))
        return req

    def complete_sync(self) -> Tuple[Tuple[int, RpcId], ...]:
        """All backups acked the in-flight sync.  Advances the synced frontier
        and returns the (keyhash, rpc_id) gc entries for the witnesses (§3.5)."""
        assert self.sync_in_progress is not None
        through = self.sync_in_progress.through_index
        gc_entries: List[Tuple[int, RpcId]] = []
        for entry in self.log[self.synced_index:through]:
            # gc entries enumerate the op's (hash, class) pairs — the same
            # identity the witnesses recorded — so e.g. an HMSET's derived
            # per-field FIELD slots are collected, not just the base key's.
            for kh, _cls in entry.op.hash_classes():
                gc_entries.append((kh, entry.op.rpc_id))
            self._window_remove(entry.op)
        self.rifl.mark_synced_through(
            entry.op.rpc_id for entry in self.log[self.synced_index:through]
        )
        count = through - self.synced_index
        self.synced_index = through
        self.sync_in_progress = None
        self.stats["batch_syncs"] += 1
        self._m_batch_syncs.inc()
        jr = self.journal
        if jr is not None:
            jr.emit("sync", actor=self.journal_actor, mid=self.master_id,
                    through=through, count=count)
        return tuple(gc_entries)

    def force_synced_through(self, through: int) -> None:
        """Advance the synced frontier without the single-outstanding-sync
        bookkeeping.  Used by the 'original primary-backup' simulation mode,
        which issues one replication RPC set per op (no batching, multiple
        outstanding) — the pre-CURP RAMCloud behaviour."""
        if through <= self.synced_index:
            return
        assert self.sync_in_progress is None
        for entry in self.log[self.synced_index:through]:
            self._window_remove(entry.op)
        self.rifl.mark_synced_through(
            e.op.rpc_id for e in self.log[self.synced_index:through]
        )
        count = through - self.synced_index
        self.synced_index = through
        self.want_sync = False
        jr = self.journal
        if jr is not None:
            jr.emit("sync", actor=self.journal_actor, mid=self.master_id,
                    through=through, count=count)

    def abort_sync(self) -> None:
        """A backup rejected (e.g. zombie epoch fence): drop the attempt."""
        self.sync_in_progress = None
        self.want_sync = True

    # -------------------------------------------------------------- recovery
    def restore_from_log(self, entries: Sequence[LogEntry]) -> None:
        """New master: rebuild state machine + RIFL from a backup's log."""
        for e in entries:
            self.store.execute(e.op, 0.0)
            if e.op.op_type is OpType.MIGRATE_IN:
                # Moved-in completion records are log-resident (they rode the
                # transfer op): re-surface them so cross-move retries still
                # dedup after this failover.
                self._install_migrated(e.op)
            self.rifl.record_completion(e.op.rpc_id, e.result, synced=True)
        self.log = list(entries)
        self.synced_index = len(self.log)
        self._unsynced_keyhash.clear()

    def replay_from_witness(self, requests: Sequence[Op]) -> int:
        """Replay witness data; RIFL filters ops that already made it to
        backups (§3.3).  Client acks are ignored while replaying (§4.8).

        With the merge lattice, a witness may hold SEVERAL live records of
        one key (concurrent INCRs/SADDs/...), so the replay is a merge-FOLD,
        not a last-writer-wins pick: every surviving request re-executes
        through the state machine, whose merge-op semantics (repro.core.store)
        are order-insensitive within a class.  Requests are additionally
        sorted by rpc_id so two recoveries (or recovery vs a differently-
        ordered witness extraction) produce bit-identical logs — order only
        matters for the log/backup byte stream, never for the merged state.
        Returns number of ops actually re-executed."""
        self.rifl.replay_mode = True
        executed = 0
        for op in sorted(requests, key=lambda o: o.rpc_id):
            if not self.owns(op):
                continue  # §3.6: migrated partition remnants are ignored
            if self.rifl.check_duplicate(op.rpc_id) is not None:
                continue
            result = self.store.execute(op, 0.0)
            self.rifl.record_completion(op.rpc_id, result, synced=False)
            self.log.append(LogEntry(op, result))
            self._window_add(op)
            executed += 1
        self.rifl.replay_mode = False
        self.want_sync = executed > 0 or self.unsynced_count > 0
        return executed
