"""Core protocol types for CURP (Consistent Unordered Replication Protocol).

Everything here is transport-agnostic: the discrete-event simulator (repro.sim)
and the local in-process harness (repro.core.local) both drive these same
dataclasses through the same state machines.

Key hashing follows the paper (§4.2): commutativity checks compare 64-bit
hashes of primary keys, not full keys.  We use splitmix64, the same avalanche
mixer validated in the Pallas kernel (repro.kernels.keyhash).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple

MASK64 = (1 << 64) - 1

# RPC identity per RIFL: (client_id, per-client monotonically increasing seq).
RpcId = Tuple[int, int]


def splitmix64(x: int) -> int:
    """SplitMix64 finalizer: a cheap, well-avalanched 64-bit mixer."""
    x = (x + 0x9E3779B97F4A7C15) & MASK64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64


def keyhash(key: Any) -> int:
    """64-bit primary-key hash used for all commutativity checks."""
    if isinstance(key, int):
        return splitmix64(key)
    if isinstance(key, str):
        key = key.encode()
    h = 0xCBF29CE484222325  # FNV-1a over the bytes, then splitmix finish.
    for b in key:
        h = ((h ^ b) * 0x100000001B3) & MASK64
    return splitmix64(h)


class OpType(enum.Enum):
    SET = "SET"
    GET = "GET"
    INCR = "INCR"
    HMSET = "HMSET"       # hashmap member set (Redis-style, Fig. 10)
    MSET = "MSET"         # multi-key atomic set (exercises multi-key witness path)
    DEL = "DEL"
    NOOP = "NOOP"
    # CRDT-flavoured merge ops (repro.core.merge): commute with themselves
    # by construction, so the widened witness check admits concurrent
    # same-key pairs on the 1-RTT fast path.
    SADD = "SADD"         # set-add (union merge)
    APPEND = "APPEND"     # append (canonical sorted-chunks merge)
    MAX = "MAX"           # bounded max (idempotent, commutative)
    # Mini-transaction subsystem (repro.core.txn): single-shard atomic
    # read+write op, and the per-shard legs of the RIFL-identified 2PC.
    TXN = "TXN"                   # single-shard read-set + write-set, 1 RTT
    TXN_PREPARE = "TXN_PREPARE"   # participant: install intent + lock keys
    TXN_COMMIT = "TXN_COMMIT"     # participant: apply write-set, drop intent
    TXN_ABORT = "TXN_ABORT"       # participant: drop intent (or tombstone)
    # Live reconfiguration (repro.core.migration): slot-handover transfer
    # legs.  Issued only by the MigrationManager, never by clients; they ride
    # the masters' ordinary log + backup-sync machinery so a moved slot's
    # data (and its RIFL completion records) survive either side crashing.
    MIGRATE_IN = "MIGRATE_IN"     # receiver: absorb (kvs, rifl records)
    MIGRATE_OUT = "MIGRATE_OUT"   # donor: durably drop the moved keys


# Which ops are updates (need durability) vs reads.
UPDATE_OPS = {OpType.SET, OpType.INCR, OpType.HMSET, OpType.MSET, OpType.DEL,
              OpType.SADD, OpType.APPEND, OpType.MAX,
              OpType.TXN, OpType.TXN_PREPARE, OpType.TXN_COMMIT,
              OpType.TXN_ABORT, OpType.MIGRATE_IN, OpType.MIGRATE_OUT}

# The 2PC leg ops (never issued by clients directly; the coordinator in
# repro.core.txn drives them).
TXN_OPS = {OpType.TXN_PREPARE, OpType.TXN_COMMIT, OpType.TXN_ABORT}


@dataclass(frozen=True)
class Op:
    """A client operation = the unit of replication.

    ``keys`` is the full affected key set (one entry for single-key ops).
    ``args`` carries values (SET payload, HMSET field/value, ...).
    """
    op_type: OpType
    keys: Tuple[Any, ...]
    args: Tuple[Any, ...] = ()
    rpc_id: RpcId = (0, 0)

    @property
    def is_update(self) -> bool:
        return self.op_type in UPDATE_OPS

    def key_hashes(self) -> Tuple[int, ...]:
        # Memoized: the hot paths (witness records, window checks, gc entry
        # building) re-ask several times per op; keys are frozen.
        khs = self.__dict__.get("_khs")
        if khs is None:
            khs = tuple(keyhash(k) for k in self.keys)
            object.__setattr__(self, "_khs", khs)
        return khs

    def hash_classes(self) -> Tuple[Tuple[int, int], ...]:
        """Memoized ``(key_hash, merge-class)`` pairs (repro.core.merge).

        This is the commutativity identity of the op: what witnesses record,
        masters refcount in the unsynced window, and gc entries enumerate.
        ``key_hashes()`` stays the ROUTING identity (one hash per key);
        HMSET's derived per-field FIELD pairs appear only here."""
        hcs = self.__dict__.get("_hcs")
        if hcs is None:
            from .merge import op_hash_classes   # lazy: merge imports types

            hcs = tuple(op_hash_classes(self))
            object.__setattr__(self, "_hcs", hcs)
        return hcs


class RecordStatus(enum.Enum):
    ACCEPTED = "ACCEPTED"
    REJECTED = "REJECTED"


class WitnessMode(enum.Enum):
    NORMAL = "NORMAL"
    RECOVERY = "RECOVERY"   # irreversible after getRecoveryData (§4.1)
    ENDED = "ENDED"


@dataclass
class ExecResult:
    """Master's reply to an update/read RPC."""
    value: Any
    synced: bool            # True => master synced before replying (§3.2.3 tag)
    ok: bool = True
    error: Optional[str] = None   # e.g. "WRONG_WITNESS_VERSION", "NOT_OWNER"


@dataclass
class CompletionRecord:
    """RIFL completion record: durable (rpc_id -> result) pair."""
    rpc_id: RpcId
    result: Any
    synced: bool = False    # replicated to backups yet?


# ---------------------------------------------------------------------------
# RPC message payloads (Fig. 4 of the paper + the client<->master RPCs).
# The simulator wraps these in envelopes with src/dst/time.
# ---------------------------------------------------------------------------

@dataclass
class UpdateReq:
    op: Op
    witness_list_version: int
    client_acks: Tuple[Tuple[int, int], ...] = ()  # RIFL piggybacked acks


@dataclass
class UpdateResp:
    rpc_id: RpcId
    result: ExecResult


@dataclass
class ReadReq:
    op: Op


@dataclass
class ReadResp:
    rpc_id: RpcId
    result: ExecResult


@dataclass
class SyncReq:
    """Client asks master to flush unsynced ops (slow path)."""
    rpc_id: RpcId           # the op the client is trying to make durable


@dataclass
class SyncResp:
    rpc_id: RpcId
    ok: bool


@dataclass
class RecordReq:
    """CLIENT -> WITNESS (Fig. 4): record(masterID, keyHashes, rpcId, request)."""
    master_id: int
    key_hashes: Tuple[int, ...]
    rpc_id: RpcId
    request: Op


@dataclass
class RecordResp:
    rpc_id: RpcId
    status: RecordStatus


@dataclass
class GcReq:
    """MASTER -> WITNESS: gc(list of {keyHash, rpcId})."""
    entries: Tuple[Tuple[int, RpcId], ...]


@dataclass
class GcResp:
    stale_requests: Tuple[Op, ...]   # suspected uncollected garbage (§4.5)


@dataclass
class GetRecoveryDataReq:
    master_id: int


@dataclass
class GetRecoveryDataResp:
    requests: Tuple[Op, ...]


@dataclass
class StartWitnessReq:
    master_id: int


@dataclass
class EndWitnessReq:
    pass


@dataclass
class BackupSyncReq:
    """MASTER -> BACKUP: ordered log segment [from_index, from_index+len)."""
    master_id: int
    epoch: int               # master epoch; backups reject stale masters (§4.7)
    from_index: int
    entries: Tuple[Any, ...]  # (op, result) pairs, order = master execution order


@dataclass
class BackupSyncResp:
    ok: bool
    synced_through: int


@dataclass
class ClusterConfig:
    """Published by the configuration manager (§3.6)."""
    master_id: int
    epoch: int
    backup_ids: Tuple[int, ...]
    witness_ids: Tuple[int, ...]
    witness_list_version: int
