"""CURP client-side completion logic (§3.2.1).

The decision rule is small and pure, so both harnesses (the in-process
LocalCluster and the discrete-event simulator) share it:

  * master replied with ``synced=True``           -> COMPLETE (conflict path,
      2 RTTs total; no witness accepts needed)
  * master replied fast AND all f witnesses ACCEPTED -> COMPLETE (1 RTT)
  * master replied fast but >=1 witness rejected  -> NEED_SYNC: issue a sync
      RPC to the master; once acked                -> COMPLETE (2-3 RTTs)
  * master error (stale witness list / not owner) -> REFETCH config and retry
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

from .types import ExecResult, Op, OpType, RecordStatus, RpcId


class Decision(enum.Enum):
    COMPLETE = "COMPLETE"
    NEED_SYNC = "NEED_SYNC"
    REFETCH_CONFIG = "REFETCH_CONFIG"


def decide(
    result: ExecResult, witness_statuses: Sequence[RecordStatus]
) -> Decision:
    if not result.ok:
        return Decision.REFETCH_CONFIG
    if result.synced:
        return Decision.COMPLETE
    if all(s is RecordStatus.ACCEPTED for s in witness_statuses):
        return Decision.COMPLETE
    return Decision.NEED_SYNC


def decide_multi(
    parts: Sequence[Tuple[ExecResult, Sequence[RecordStatus]]]
) -> Decision:
    """Client completion rule for a multi-shard op (one sub-op per shard).

    COMPLETE means the client owes no further RPCs: every shard's sub-op is
    durable, either via that shard's full witness accept set (1 RTT) or
    because that shard's master tagged its result synced (the master already
    paid the sync before replying — 2 RTTs on that shard, but nothing left
    for the client to do).  A stale config at any shard forces a refetch;
    otherwise NEED_SYNC means the client must issue explicit sync RPCs — but
    only to the shards whose own ``decide`` returned NEED_SYNC.  Note
    COMPLETE is about completion, not latency: the op counts as 1-RTT only
    if additionally every shard's verdict was fast (see ShardedCluster.mset).
    """
    return combine_decisions(decide(result, statuses)
                             for result, statuses in parts)


def combine_decisions(decisions) -> Decision:
    """Fold per-shard ``decide`` outcomes into the op-level decision (the
    single source of truth for both decide_multi and harnesses that already
    hold the per-shard decisions)."""
    decisions = list(decisions)
    if any(d is Decision.REFETCH_CONFIG for d in decisions):
        return Decision.REFETCH_CONFIG
    if all(d is Decision.COMPLETE for d in decisions):
        return Decision.COMPLETE
    return Decision.NEED_SYNC


def decide_commit(votes, n_parts: int) -> bool:
    """Coordinator-side 2PC decision rule (repro.core.txn): COMMIT iff every
    participant leg voted yes — a vote is granted only once that leg's
    prepare is durable (all-witness accept or synced), so this is the same
    completion discipline as ``decide``, lifted to transaction legs.  A
    short vote set (coordinator died mid-prepare-round) can never commit.
    """
    votes = list(votes)
    return len(votes) == n_parts and all(v.granted for v in votes)


@dataclass
class ClientSession:
    """Per-client RIFL identity: rpc_id allocation + ack tracking."""
    client_id: int
    _seq: itertools.count = field(default_factory=lambda: itertools.count(1))
    first_incomplete: int = 1
    _completed: set = field(default_factory=set)

    def next_rpc_id(self) -> RpcId:
        return (self.client_id, next(self._seq))

    def mark_completed(self, rpc_id: RpcId) -> None:
        self._completed.add(rpc_id[1])
        while self.first_incomplete in self._completed:
            self._completed.discard(self.first_incomplete)
            self.first_incomplete += 1

    def abandon(self, rpc_id: RpcId) -> None:
        """Release an allocated identity that was NEVER transmitted to any
        master or witness (e.g. the op drew a SlotMoving redirect at the
        routing stage).  Without this the ack frontier would stall at the
        abandoned seq forever, pinning every later completion record at
        every master.  MUST NOT be called for an op that may have reached a
        master: advancing the frontier past a live op's seq would let its
        completion record be deleted before the client saw the result."""
        self.mark_completed(rpc_id)

    def acks(self) -> Tuple[Tuple[int, int], ...]:
        """Piggybacked RIFL ack: 'I have seen results for all seq < N'."""
        return ((self.client_id, self.first_incomplete),)

    # convenience constructors -------------------------------------------------
    def op_set(self, key, value) -> Op:
        return Op(OpType.SET, (key,), (value,), self.next_rpc_id())

    def op_get(self, key) -> Op:
        return Op(OpType.GET, (key,), (), self.next_rpc_id())

    def op_incr(self, key, delta: int = 1) -> Op:
        return Op(OpType.INCR, (key,), (delta,), self.next_rpc_id())

    def op_hmset(self, key, fields) -> Op:
        return Op(OpType.HMSET, (key,), (tuple(fields),), self.next_rpc_id())

    def op_mset(self, kvs) -> Op:
        keys = tuple(k for k, _ in kvs)
        vals = tuple(v for _, v in kvs)
        return Op(OpType.MSET, keys, vals, self.next_rpc_id())

    def op_del(self, key) -> Op:
        return Op(OpType.DEL, (key,), (), self.next_rpc_id())

    def op_sadd(self, key, member) -> Op:
        return Op(OpType.SADD, (key,), (member,), self.next_rpc_id())

    def op_append(self, key, chunk) -> Op:
        return Op(OpType.APPEND, (key,), (chunk,), self.next_rpc_id())

    def op_max(self, key, n) -> Op:
        return Op(OpType.MAX, (key,), (n,), self.next_rpc_id())
