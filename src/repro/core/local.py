"""LocalCluster: an in-process, instantly-delivered CURP cluster.

This harness exists for deterministic protocol testing and for the examples:
every RPC is a function call, but the *protocol steps are the real ones* —
witness records, speculative execution, batched syncs, gc, recovery, witness
reconfiguration.  Timing behaviour (latency/throughput) lives in repro.sim.

Fault injection knobs let tests exercise the interesting interleavings:
  * ``witness_drop(witness_idx)``: client's record RPC to that witness is lost.
  * ``crash_master(lose_unsynced=True)``: master dies; unsynced state is gone;
    recovery runs per §3.3 onto a fresh master.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .backup import Backup
from .client import ClientSession, Decision, decide
from .config import ConfigManager
from .master import DUP, ERROR, FAST, SYNCED, Master
from .recovery import RecoveryReport, recover_master
from .types import (
    ClusterConfig,
    ExecResult,
    Op,
    RecordStatus,
    WitnessMode,
)
from .witness import Witness


@dataclass
class OpOutcome:
    value: Any
    rtts: int                 # logical round-trips the client experienced
    fast_path: bool           # completed via 1-RTT witness path
    synced_path: bool         # master tagged result synced (conflict)
    witness_accepts: int


class LocalCluster:
    def __init__(
        self,
        f: int = 3,
        sync_batch: int = 50,
        witness_sets: int = 1024,
        witness_ways: int = 4,
        hot_key_window: float = 0.0,
        seed: int = 0,
        auto_sync: bool = True,
    ) -> None:
        self.f = f
        self.rng = random.Random(seed)
        self.auto_sync = auto_sync
        self.config = ConfigManager()
        self._next_node_id = 0
        self.master = Master(
            self._node_id(), epoch=0, sync_batch=sync_batch,
            hot_key_window=hot_key_window,
        )
        self.backups = [Backup(self._node_id()) for _ in range(f)]
        self.witnesses = [
            Witness(witness_sets, witness_ways) for _ in range(f)
        ]
        self._witness_ids = tuple(self._node_id() for _ in range(f))
        for w in self.witnesses:
            w.start(self.master.master_id)
        self.config.publish(0, ClusterConfig(
            master_id=self.master.master_id,
            epoch=0,
            backup_ids=tuple(b.backup_id for b in self.backups),
            witness_ids=self._witness_ids,
            witness_list_version=0,
        ))
        self._dropped_witnesses: set[int] = set()
        self.history: List[dict] = []   # linearizability-checkable op log

    def _node_id(self) -> int:
        self._next_node_id += 1
        return self._next_node_id

    # ------------------------------------------------------------------ faults
    def witness_drop(self, witness_idx: int, dropped: bool = True) -> None:
        if dropped:
            self._dropped_witnesses.add(witness_idx)
        else:
            self._dropped_witnesses.discard(witness_idx)

    # ----------------------------------------------------------------- client
    def new_client(self) -> ClientSession:
        return ClientSession(client_id=self._node_id())

    def update(self, session: ClientSession, op: Op, now: float = 0.0) -> OpOutcome:
        """Full CURP update: update RPC + parallel witness records."""
        for _attempt in range(4):
            cfg = self.config.fetch(0)
            # 1 RTT: client -> master (speculative) and client -> witnesses.
            verdict, result = self.master.handle_update(
                op, cfg.witness_list_version, session.acks(), now
            )
            if verdict == ERROR:
                # Stale witness list / migration: refetch config and retry.
                continue

            statuses = []
            for i, w in enumerate(self.witnesses):
                if i in self._dropped_witnesses:
                    statuses.append(RecordStatus.REJECTED)  # timeout == reject
                else:
                    statuses.append(
                        w.record(cfg.master_id, op.key_hashes(), op.rpc_id, op)
                    )

            if verdict == SYNCED:
                self._drain_syncs()
                decision = Decision.COMPLETE
                rtts, fast = 2, False
            else:
                decision = decide(result, statuses)
                rtts, fast = (1, True) if decision is Decision.COMPLETE else (2, False)

            if decision is Decision.NEED_SYNC:
                # Slow path: explicit sync RPC.
                self._drain_syncs()
                decision = Decision.COMPLETE

            if self.auto_sync and self.master.want_sync:
                self._drain_syncs()

            session.mark_completed(op.rpc_id)
            out = OpOutcome(
                value=result.value,
                rtts=rtts,
                fast_path=fast and verdict == FAST,
                synced_path=verdict == SYNCED,
                witness_accepts=sum(
                    1 for s in statuses if s is RecordStatus.ACCEPTED
                ),
            )
            self.history.append({
                "op": op, "value": result.value, "client": session.client_id,
            })
            return out
        raise RuntimeError("update retries exhausted")

    def read(self, session: ClientSession, op: Op, now: float = 0.0) -> OpOutcome:
        verdict, result = self.master.handle_read(op, now)
        if verdict == SYNCED:
            self._drain_syncs()
        self.history.append({
            "op": op, "value": result.value, "client": session.client_id,
        })
        return OpOutcome(
            value=result.value,
            rtts=1 if verdict == FAST else 2,
            fast_path=verdict == FAST,
            synced_path=verdict == SYNCED,
            witness_accepts=0,
        )

    def read_from_backup(
        self, session: ClientSession, op: Op, backup_idx: int = 0,
        witness_idx: int = 0,
    ) -> Tuple[Any, bool]:
        """§A.1 consistent read from a (local) backup: check commutativity with
        a (local) witness first.  Returns (value, served_by_backup)."""
        w = self.witnesses[witness_idx]
        if w.commutes_with_all(op.key_hashes()):
            # Backup value is guaranteed fresh: rebuild view from its log.
            from .store import KVStore

            view = KVStore()
            for e in self.backups[backup_idx].get_log():
                view.execute(e.op)
            return view.get(op.keys[0]), True
        # Witness holds a non-commutative record: must go to the master.
        out = self.read(session, op)
        return out.value, False

    # ------------------------------------------------------------------ syncs
    def _drain_syncs(self) -> None:
        """Run batched backup syncs + witness gc until quiescent (§4.4, §3.5)."""
        while True:
            req = self.master.begin_sync()
            if req is None:
                return
            ok = True
            for b in self.backups:
                resp = b.handle_sync(req)
                ok = ok and resp.ok
            if not ok:
                self.master.abort_sync()
                return
            gc_entries = self.master.complete_sync()
            for i, w in enumerate(self.witnesses):
                if i not in self._dropped_witnesses:
                    resp = w.gc(gc_entries)
                    # §4.5: retry suspected uncollected garbage through RIFL.
                    for op in resp.stale_requests:
                        self.master.handle_update(
                            op, self.config.fetch(0).witness_list_version, (), 0.0
                        )

    def sync_now(self) -> None:
        self.master.want_sync = True
        self._drain_syncs()

    # --------------------------------------------------------------- recovery
    def crash_master(self) -> RecoveryReport:
        """Kill the master (unsynced state is lost) and recover a new one from
        backups + one witness (§3.3)."""
        old_id = self.master.master_id
        new_master = Master(
            self._node_id(),
            sync_batch=self.master.sync_batch,
            hot_key_window=self.master.hot_key_window,
        )
        # Pick any reachable witness (here: first non-dropped).
        live = [i for i in range(self.f) if i not in self._dropped_witnesses]
        assert live, "no witness reachable: recovery must wait (§3.3)"
        recovery_witness = self.witnesses[live[0]]
        new_witnesses = [
            Witness(recovery_witness.n_sets, recovery_witness.n_ways)
            for _ in range(self.f)
        ]
        new_ids = tuple(self._node_id() for _ in range(self.f))
        report = recover_master(
            shard_id=0,
            old_master_id=old_id,
            new_master=new_master,
            backups=self.backups,
            recovery_witness=recovery_witness,
            new_witnesses=new_witnesses,
            new_witness_ids=new_ids,
            config=self.config,
        )
        self.master = new_master
        self.witnesses = new_witnesses
        self._witness_ids = new_ids
        self._dropped_witnesses.clear()
        return report

    def replace_witness(self, witness_idx: int) -> None:
        """§3.6 case 2: decommission a witness, install a fresh one, bump the
        WitnessListVersion; master syncs before the new config goes live."""
        dead_id = self._witness_ids[witness_idx]
        new_w = Witness(
            self.witnesses[witness_idx].n_sets, self.witnesses[witness_idx].n_ways
        )
        new_id = self._node_id()
        self.sync_now()  # master must sync to restore f fault tolerance
        cfg = self.config.replace_witness(0, dead_id, new_id)
        self.master.witness_list_version = cfg.witness_list_version
        new_w.start(self.master.master_id)
        self.witnesses[witness_idx] = new_w
        ids = list(self._witness_ids)
        ids[witness_idx] = new_id
        self._witness_ids = tuple(ids)
