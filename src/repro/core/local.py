"""LocalCluster: an in-process, instantly-delivered CURP cluster.

This harness exists for deterministic protocol testing and for the examples:
every RPC is a function call, but the *protocol steps are the real ones* —
witness records, speculative execution, batched syncs, gc, recovery, witness
reconfiguration.  Timing behaviour (latency/throughput) lives in repro.sim.

Shard model: the protocol drive loop lives in repro.core.shard.ShardGroup —
one master plus its own witness group and backups.  LocalCluster is exactly
one ShardGroup (the single-master harness the unit tests exercise);
ShardedCluster (same module) is N of them behind a KeyRouter, which is how
the paper deploys CURP on a partitioned store (§4, Fig. 3).

Fault injection knobs let tests exercise the interesting interleavings:
  * ``witness_drop(witness_idx)``: client's record RPC to that witness is lost.
  * ``crash_master(lose_unsynced=True)``: master dies; unsynced state is gone;
    recovery runs per §3.3 onto a fresh master.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Tuple

from .client import ClientSession
from .config import ConfigManager
from .recovery import RecoveryReport
from .shard import HistoryRecorder, ShardGroup
from .types import Op


@dataclass
class OpOutcome:
    value: Any
    rtts: int                 # logical round-trips the client experienced
    fast_path: bool           # completed via 1-RTT witness path
    synced_path: bool         # master tagged result synced (conflict)
    witness_accepts: int


class LocalCluster:
    """Single-master CURP harness: a thin shell over one ShardGroup."""

    def __init__(
        self,
        f: int = 3,
        sync_batch: int = 50,
        witness_sets: int = 1024,
        witness_ways: int = 4,
        hot_key_window: float = 0.0,
        seed: int = 0,
        auto_sync: bool = True,
        geometry=None,
        witness_backend: str = "python",
    ) -> None:
        self.f = f
        self.rng = random.Random(seed)
        self.config = ConfigManager()
        self._next_node_id = 0
        self._record = HistoryRecorder()
        self.history = self._record.history   # linearizability-checkable log
        self.group = ShardGroup(
            shard_id=0, config=self.config, alloc_id=self._node_id,
            f=f, sync_batch=sync_batch, witness_sets=witness_sets,
            witness_ways=witness_ways, hot_key_window=hot_key_window,
            auto_sync=auto_sync, record=self._record, geometry=geometry,
            witness_backend=witness_backend,
        )

    def _node_id(self) -> int:
        self._next_node_id += 1
        return self._next_node_id

    # ------------------------------------------------- group state passthrough
    @property
    def master(self):
        return self.group.master

    @property
    def backups(self):
        return self.group.backups

    @property
    def witnesses(self):
        return self.group.witnesses

    @property
    def auto_sync(self) -> bool:
        return self.group.auto_sync

    @auto_sync.setter
    def auto_sync(self, v: bool) -> None:
        self.group.auto_sync = v

    # ------------------------------------------------------------------ faults
    def witness_drop(self, witness_idx: int, dropped: bool = True) -> None:
        self.group.witness_drop(witness_idx, dropped)

    # ----------------------------------------------------------------- client
    def new_client(self) -> ClientSession:
        return ClientSession(client_id=self._node_id())

    def update(self, session: ClientSession, op: Op, now: float = 0.0) -> OpOutcome:
        """Full CURP update: update RPC + parallel witness records."""
        return self.group.update(session, op, now)

    def update_batch(self, session: ClientSession, ops, now: float = 0.0):
        """Batched updates: one master round + one record invocation per
        witness for the whole batch (see ShardGroup.update_batch)."""
        return self.group.update_batch(session, ops, now)

    def read(self, session: ClientSession, op: Op, now: float = 0.0) -> OpOutcome:
        return self.group.read(session, op, now)

    def read_from_backup(
        self, session: ClientSession, op: Op, backup_idx: int = 0,
        witness_idx: int = 0,
    ) -> Tuple[Any, bool]:
        """§A.1 consistent read from a (local) backup: check commutativity with
        a (local) witness first.  Returns (value, served_by_backup)."""
        return self.group.read_from_backup(session, op, backup_idx, witness_idx)

    # ------------------------------------------------------------------ syncs
    def _drain_syncs(self) -> None:
        self.group._drain_syncs()

    def sync_now(self) -> None:
        self.group.sync_now()

    # --------------------------------------------------------------- recovery
    def crash_master(self) -> RecoveryReport:
        """Kill the master (unsynced state is lost) and recover a new one from
        backups + one witness (§3.3)."""
        return self.group.crash_master()

    def replace_witness(self, witness_idx: int) -> None:
        """§3.6 case 2: decommission a witness, install a fresh one, bump the
        WitnessListVersion; master syncs before the new config goes live."""
        self.group.replace_witness(witness_idx)
