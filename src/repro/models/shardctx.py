"""Activation-sharding context.

Model code stays mesh-agnostic: layers call ``constrain(x, kind)`` at the
boundaries that matter (residual stream, attention heads, FFN hidden, MoE
expert dim, logits).  Launchers/dry-run install concrete NamedShardings for
each kind before tracing; with no rules installed every call is a no-op
(smoke tests on 1 device).

Kinds:
  residual    [B, S, D]
  heads       [B, S, H, dh]
  ffn         [B, S, F]
  moe         [B, S, E, F]
  logits      [B, S, V]
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Optional

import jax

_RULES: Dict[str, object] = {}


@contextmanager
def activation_sharding(rules: Dict[str, object]):
    global _RULES
    old = _RULES
    _RULES = dict(rules)
    try:
        yield
    finally:
        _RULES = old


def constrain(x, kind: str):
    s = _RULES.get(kind)
    if s is None:
        return x
    try:
        if x.ndim != len(s.spec):
            return x
    except AttributeError:
        pass
    return jax.lax.with_sharding_constraint(x, s)


def get_rule(kind: str):
    """Inspect the installed rule (layers pick TP vs sequence-parallel
    attention layouts from it)."""
    return _RULES.get(kind)


def heads_are_tp() -> bool:
    """True iff the 'heads' rule shards the head dim (dim 2 of [B,S,H,dh])."""
    r = _RULES.get("heads")
    if r is None:
        return False
    try:
        spec = r.spec
        return len(spec) >= 3 and spec[2] is not None
    except AttributeError:
        return False
