"""Unified model: one code path drives all 10 assigned architectures.

Layer stacking uses jax.lax.scan over STACKED per-layer params (compact HLO —
essential for 96-layer configs and 1-core CPU compiles; also what you want on
a real pod for compile time).  Models with a few "special" layers (Hymba's 3
global-attention layers among sliding-window layers) are segmented:

    [single 0] [scan 1..14] [single 15] [scan 16..30] [single 31]

so every scan segment is homogeneous and decode caches stay tight (window-
sized KV for SWA layers, full-length KV only for the global layers).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    attention_decode,
    attention_train,
    init_attn_params,
    init_mlp_params,
    mlp,
    rmsnorm,
)
from .moe import init_moe_params, moe_forward
from .shardctx import constrain
from .ssm import init_ssm_cache, init_ssm_params, ssm_decode, ssm_train


# ----------------------------------------------------------------------------
# segmentation
# ----------------------------------------------------------------------------
def segments(cfg: ModelConfig) -> List[Tuple[str, int, int]]:
    """[("scan"|"single", start, end)] covering 0..n_layers in order."""
    if cfg.attn != "swa" or not cfg.global_attn_layers:
        return [("scan", 0, cfg.n_layers)]
    segs: List[Tuple[str, int, int]] = []
    cur = 0
    for g in sorted(cfg.global_attn_layers):
        if g > cur:
            segs.append(("scan", cur, g))
        segs.append(("single", g, g + 1))
        cur = g + 1
    if cur < cfg.n_layers:
        segs.append(("scan", cur, cfg.n_layers))
    return segs


def _slice_layers(layer_params, start: int, end: int):
    return jax.tree_util.tree_map(lambda a: a[start:end], layer_params)


def _layer(layer_params, i: int):
    return jax.tree_util.tree_map(lambda a: a[i], layer_params)


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------
def _init_block_params(cfg: ModelConfig, key, dtype) -> Dict:
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if cfg.has_attn:
        p["attn"] = init_attn_params(cfg, keys[0], dtype)
    if cfg.ssm:
        p["ssm"] = init_ssm_params(cfg, keys[1], dtype)
    if cfg.has_moe:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["moe"] = init_moe_params(cfg, keys[2], dtype)
    elif cfg.has_dense_mlp:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["mlp"] = init_mlp_params(cfg, keys[3], dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_layers, k_head, k_fe = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if cfg.frontend == "token":
        params["embed"] = (
            jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dtype)
            * cfg.d_model ** -0.5
        )
    else:
        fd = cfg.frontend_dim or cfg.d_model
        params["frontend_proj"] = (
            jax.random.normal(k_fe, (fd, cfg.d_model), dtype) * fd ** -0.5
        )
        params["embed"] = (
            jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dtype)
            * cfg.d_model ** -0.5
        )
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda k: _init_block_params(cfg, k, dtype)
    )(layer_keys)
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab), dtype)
            * cfg.d_model ** -0.5
        )
    return params


# ----------------------------------------------------------------------------
# forward (train / encode / prefill-logits)
# ----------------------------------------------------------------------------
def _block_train(cfg: ModelConfig, p: Dict, x, positions, is_global):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    parts = []
    if cfg.has_attn:
        parts.append(attention_train(cfg, p["attn"], h, positions, is_global))
    aux = jnp.zeros((), jnp.float32)
    if cfg.ssm:
        parts.append(ssm_train(cfg, p["ssm"], h))
    mix = parts[0] if len(parts) == 1 else (parts[0] + parts[1]) * 0.5
    x = x + mix
    if cfg.has_moe:
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        out, aux = moe_forward(cfg, p["moe"], h2)
        x = x + out
    elif cfg.has_dense_mlp:
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp(cfg, p["mlp"], h2)
    return constrain(x, "residual"), aux


def embed_inputs(cfg: ModelConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    if cfg.frontend == "token":
        x = params["embed"][batch["tokens"]]
    else:
        # audio / vision stubs: precomputed frame/patch embeddings (spec).
        x = batch["embeds"] @ params["frontend_proj"]
    return constrain(x, "residual")


def forward(
    cfg: ModelConfig, params: Dict, batch: Dict,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits [B,S,V], aux_loss)."""
    x = embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    def scan_block(carry, layer_p):
        x, aux = carry
        fn = _block_train
        if cfg.remat:
            fn = jax.checkpoint(
                _block_train, static_argnums=(0, 4), prevent_cse=False
            )
        x, a = fn(cfg, layer_p, x, positions, False)
        return (x, aux + a), None

    def scan_block_global(carry, layer_p):
        x, aux = carry
        fn = _block_train
        if cfg.remat:
            fn = jax.checkpoint(
                _block_train, static_argnums=(0, 4), prevent_cse=False
            )
        x, a = fn(cfg, layer_p, x, positions, True)
        return (x, aux + a), None

    for kind, s, e in segments(cfg):
        seg_params = _slice_layers(params["layers"], s, e)
        if kind == "scan":
            is_global = cfg.attn == "full"
            body = scan_block_global if is_global else scan_block
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), seg_params,
                unroll=(e - s) if cfg.scan_unroll else 1,
            )
        else:
            lp = _layer(params["layers"], s)
            x, a = _block_train(cfg, lp, x, positions, cfg.layer_is_global(s))
            aux_total = aux_total + a

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = constrain(x @ head, "logits")
    return logits, aux_total


def loss_fn(
    cfg: ModelConfig, params: Dict, batch: Dict,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    ll = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = jnp.asarray(nll.size, jnp.float32)
    ce = jnp.sum(nll) / denom
    total = ce + cfg.router_aux_coef * aux
    return total, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------------------
# decode path (serve_step)
# ----------------------------------------------------------------------------
def init_decode_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=None,
) -> Dict:
    """Cache pytree: per segment, stacked over the segment's layers."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    segs = []
    for kind, s, e in segments(cfg):
        n = e - s
        entry: Dict[str, Any] = {}
        if cfg.has_attn:
            is_global = cfg.layer_is_global(s) if kind == "single" else (
                cfg.attn == "full"
            )
            C = max_seq if is_global else min(cfg.swa_window, max_seq)
            shape = (n, batch, C, cfg.n_kv_heads, cfg.d_head)
            entry["k"] = jnp.zeros(shape, dtype)
            entry["v"] = jnp.zeros(shape, dtype)
        if cfg.ssm:
            one = init_ssm_cache(cfg, batch, dtype)
            entry["ssm"] = jax.tree_util.tree_map(
                lambda a: jnp.zeros((n,) + a.shape, a.dtype), one
            )
        segs.append(entry)
    return {"pos": jnp.zeros((batch,), jnp.int32), "segments": segs}


def _block_decode(cfg: ModelConfig, p: Dict, x, entry, cur_pos, positions,
                  is_global, active):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    parts = []
    new_entry = dict(entry)
    if cfg.has_attn:
        o, (kc, vc) = attention_decode(
            cfg, p["attn"], h, (entry["k"], entry["v"]), cur_pos, positions,
            is_global, active,
        )
        new_entry["k"], new_entry["v"] = kc, vc
        parts.append(o)
    if cfg.ssm:
        o, new_ssm = ssm_decode(cfg, p["ssm"], h, entry["ssm"], active)
        new_entry["ssm"] = new_ssm
        parts.append(o)
    mix = parts[0] if len(parts) == 1 else (parts[0] + parts[1]) * 0.5
    x = x + mix
    if cfg.has_moe:
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        out, _ = moe_forward(cfg, p["moe"], h2)
        x = x + out
    elif cfg.has_dense_mlp:
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp(cfg, p["mlp"], h2)
    return x, new_entry


def decode_step(
    cfg: ModelConfig, params: Dict, batch: Dict, cache: Dict,
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode.  batch: {"tokens": [B,1]} (or {"embeds": [B,1,fd]});
    optional "positions" ([B,1] or [3,B,1]) and "active" ([B] int32: rows
    with 0 neither write caches nor advance).  Returns (logits [B,V], cache')."""
    x = embed_inputs(cfg, params, batch)
    B = x.shape[0]
    cur_pos = cache["pos"]                       # [B]
    active = batch.get("active")
    if active is None:
        active = jnp.ones((B,), jnp.int32)
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = cur_pos.astype(jnp.int32)[:, None]
    new_segs = []
    for (kind, s, e), entry in zip(segments(cfg), cache["segments"]):
        if kind == "single":
            lp = _layer(params["layers"], s)
            le = jax.tree_util.tree_map(lambda a: a[0], entry)
            x, ne = _block_decode(
                cfg, lp, x, le, cur_pos, positions, cfg.layer_is_global(s),
                active,
            )
            new_segs.append(
                jax.tree_util.tree_map(lambda a: a[None], ne)
            )
        else:
            seg_params = _slice_layers(params["layers"], s, e)
            is_global = cfg.attn == "full"

            def body(carry, inp):
                x = carry
                layer_p, layer_e = inp
                x, ne = _block_decode(
                    cfg, layer_p, x, layer_e, cur_pos, positions, is_global,
                    active,
                )
                return x, ne

            x, ne = jax.lax.scan(
                body, x, (seg_params, entry),
                unroll=(e - s) if cfg.scan_unroll else 1,
            )
            new_segs.append(ne)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = (x[:, 0, :] @ head).astype(jnp.float32)
    return logits, {"pos": cur_pos + active, "segments": new_segs}


def prefill(
    cfg: ModelConfig, params: Dict, batch: Dict,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Prefill forward: returns (all logits, final hidden).  (The prefill_32k
    dry-run cells lower this; serving uses forward+cache-build via decode for
    simplicity of the cache layout.)"""
    logits, _ = forward(cfg, params, batch)
    return logits[:, -1, :], logits
