"""repro.models — the unified architecture zoo (pure JAX, no Pallas)."""
from .config import ModelConfig, reduced
from .transformer import (
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
    prefill,
    segments,
)

__all__ = [
    "ModelConfig", "reduced", "decode_step", "forward", "init_decode_cache",
    "init_params", "loss_fn", "prefill", "segments",
]
