"""Shared model layers: norms, RoPE / M-RoPE, GQA attention (full + sliding
window; train, prefill, and single-token decode), dense MLPs.

Pure functions over parameter dicts; jax.lax only for control flow.  No
Pallas here by design — the dry-run roofline must reflect real XLA HLO
(DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .shardctx import constrain, heads_are_tp


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


# ----------------------------------------------------------------------------
# RoPE / M-RoPE
# ----------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, dh]; pos: [B, S] int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # [dh/2]
    ang = pos[..., None].astype(jnp.float32) * freqs   # [B, S, dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, pos3: jnp.ndarray, theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.  pos3: [3, B, S] (t/h/w position streams);
    the dh/2 frequency slots are split into 3 sections, each rotated by its
    own stream."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)                      # [half]
    # Select per-frequency position stream: [B, S, half]
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )
    pos_sel = jnp.take(pos3, sec_id, axis=0)           # [half, B, S]
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)             # [B, S, half]
    ang = pos_sel.astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _position_embed(cfg: ModelConfig, q, k, positions):
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k


# ----------------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------------
def _qkv(cfg: ModelConfig, p: Dict, x: jnp.ndarray):
    B, S, D = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask) -> jnp.ndarray:
    """q: [B,S,Hq,dh]; k,v: [B,T,Hkv,dh]; mask: [B,1,S,T] or broadcastable.

    Grouped GQA form (no KV head repeat — the repeat blocks GSPMD from
    keeping a length-sharded KV cache sharded and forces 1 GB cache
    all-gathers per decode layer).  The scores constraint keeps the T axis
    sharded; softmax and the PV contraction then lower to partial reductions
    + small psums."""
    B, S, Hq, dh = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    rep = Hq // Hkv
    qg = q.reshape(B, S, Hkv, rep, dh)
    scale = 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bsgrd,btgd->bgrst", qg, k).astype(jnp.float32) * scale
    logits = constrain(logits, "scores5")            # [B,G,rep,S,T]
    logits = jnp.where(mask[:, :, None], logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    probs = constrain(probs, "scores5")              # stay T-sharded into PV
    o = jnp.einsum("bgrst,btgd->bsgrd", probs, v)
    return o.reshape(B, S, Hq, dh)


def make_attn_mask(
    cfg: ModelConfig, S: int, is_global: bool,
) -> jnp.ndarray:
    """[1, 1, S, S] boolean mask for training/prefill."""
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    if cfg.causal:
        m = j <= i
    else:
        m = jnp.ones((S, S), bool)
    if cfg.attn == "swa" and not is_global:
        m = m & (j > i - cfg.swa_window)
    return m[None, None]


def _sdpa_blockwise(
    cfg: ModelConfig, q, k, v, *, is_global: bool, block: int = 512,
) -> jnp.ndarray:
    """Flash-style blockwise attention: online softmax over KV blocks.

    Never materializes the S x S score matrix (the peak-VMEM/HBM killer for
    the 4k/32k cells); GQA is computed grouped (no KV head repeat).  The KV
    loop is a lax.scan, unrolled when cfg.scan_unroll (cost probes).
    """
    B, S, Hq, dh = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    # nq = 16 q-blocks so the q-block axis maps 1:1 onto the 16-way "model"
    # mesh axis (sequence sharding works for ANY head count — see DESIGN §6).
    if S % 16 == 0 and S // 16 >= 128:
        qb = S // 16
    else:
        qb = min(block, S)
    kvb = min(block, S)
    nq, nk = S // qb, S // kvb
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, nq, qb, Hkv, rep, dh)
    kg = jnp.moveaxis(k.reshape(B, nk, kvb, Hkv, dh), 1, 0)   # [nk,B,kvb,Hkv,dh]
    vg = jnp.moveaxis(v.reshape(B, nk, kvb, Hkv, dh), 1, 0)
    q_pos = jnp.arange(S).reshape(nq, qb)                      # [nq, qb]

    acc0 = jnp.zeros((B, nq, qb, Hkv, rep, dh), jnp.float32)
    m0 = jnp.full((B, nq, qb, Hkv, rep), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nq, qb, Hkv, rep), jnp.float32)

    def body(carry, inp):
        acc, m, l = carry
        kblk, vblk, kidx = inp
        logits = jnp.einsum(
            "bnqhrd,bkhd->bnqhrk", qg, kblk
        ).astype(jnp.float32) * scale                          # [B,nq,qb,H,r,kvb]
        k_pos = kidx * kvb + jnp.arange(kvb)                   # [kvb]
        msk = jnp.ones((nq, qb, kvb), bool)
        if cfg.causal:
            msk = msk & (k_pos[None, None, :] <= q_pos[:, :, None])
        if cfg.attn == "swa" and not is_global:
            msk = msk & (
                k_pos[None, None, :] > q_pos[:, :, None] - cfg.swa_window
            )
        logits = jnp.where(msk[None, :, :, None, None, :], logits, -1e30)
        blk_max = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, blk_max)
        alpha = jnp.exp(m - new_m)
        pexp = jnp.exp(logits - new_m[..., None])
        acc = acc * alpha[..., None] + jnp.einsum(
            "bnqhrk,bkhd->bnqhrd", pexp.astype(q.dtype), vblk
        ).astype(jnp.float32)
        l = l * alpha + jnp.sum(pexp, axis=-1)
        return (acc, new_m, l), None

    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (kg, vg, jnp.arange(nk)),
        unroll=nk if cfg.scan_unroll else 1,
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, Hq, dh).astype(q.dtype)


def _sdpa_blockwise_flat(
    cfg: ModelConfig, q, k, v, *, is_global: bool, block: int = 512,
) -> jnp.ndarray:
    """Blockwise attention over FLAT heads (KV expanded to Hq) — the TP
    layout: Hq divides the model axis even when (G, rep) factors don't.
    The KV expansion is a local slice of a replicated array under GSPMD."""
    B, S, Hq, dh = q.shape
    rep = Hq // k.shape[2]
    k = constrain(jnp.repeat(k, rep, axis=2), "heads")
    v = constrain(jnp.repeat(v, rep, axis=2), "heads")
    qb = min(block, S)
    kvb = min(block, S)
    nq, nk = S // qb, S // kvb
    scale = 1.0 / math.sqrt(dh)
    qf = q.reshape(B, nq, qb, Hq, dh)
    kg = jnp.moveaxis(k.reshape(B, nk, kvb, Hq, dh), 1, 0)
    vg = jnp.moveaxis(v.reshape(B, nk, kvb, Hq, dh), 1, 0)
    q_pos = jnp.arange(S).reshape(nq, qb)

    acc0 = jnp.zeros((B, nq, qb, Hq, dh), jnp.float32)
    m0 = jnp.full((B, nq, qb, Hq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nq, qb, Hq), jnp.float32)

    def body(carry, inp):
        acc, m, l = carry
        kblk, vblk, kidx = inp
        logits = jnp.einsum(
            "bnqhd,bkhd->bnqhk", qf, kblk
        ).astype(jnp.float32) * scale
        k_pos = kidx * kvb + jnp.arange(kvb)
        msk = jnp.ones((nq, qb, kvb), bool)
        if cfg.causal:
            msk = msk & (k_pos[None, None, :] <= q_pos[:, :, None])
        if cfg.attn == "swa" and not is_global:
            msk = msk & (
                k_pos[None, None, :] > q_pos[:, :, None] - cfg.swa_window
            )
        logits = jnp.where(msk[None, :, :, None, :], logits, -1e30)
        blk_max = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, blk_max)
        alpha = jnp.exp(m - new_m)
        pexp = jnp.exp(logits - new_m[..., None])
        acc = acc * alpha[..., None] + jnp.einsum(
            "bnqhk,bkhd->bnqhd", pexp.astype(q.dtype), vblk
        ).astype(jnp.float32)
        l = l * alpha + jnp.sum(pexp, axis=-1)
        return (acc, new_m, l), None

    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (kg, vg, jnp.arange(nk)),
        unroll=nk if cfg.scan_unroll else 1,
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, Hq, dh).astype(q.dtype)


def attention_train(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray, positions: jnp.ndarray,
    is_global: bool | jnp.ndarray,
) -> jnp.ndarray:
    B, S, D = x.shape
    q, k, v = _qkv(cfg, p, x)
    q, k = _position_embed(cfg, q, k, positions)
    q = constrain(q, "heads")
    k = constrain(k, "kv_heads")
    v = constrain(v, "kv_heads")
    if S > 1024 and heads_are_tp():
        assert isinstance(is_global, bool)
        o = _sdpa_blockwise_flat(cfg, q, k, v, is_global=is_global)
        o = constrain(o, "heads")
        return o.reshape(B, S, cfg.n_heads * cfg.d_head) @ p["wo"]
    if S > 1024:
        # Blockwise path needs a concrete window flag; mixed swa/global
        # stacks are segmented by the caller so is_global is always a
        # Python bool on this path.
        assert isinstance(is_global, bool)
        o = _sdpa_blockwise(cfg, q, k, v, is_global=is_global)
    else:
        if isinstance(is_global, bool):
            mask = make_attn_mask(cfg, S, is_global)
        else:
            # traced per-layer flag (scan over mixed swa/global layers)
            m_g = make_attn_mask(cfg, S, True)
            m_l = make_attn_mask(cfg, S, False)
            mask = jnp.where(is_global, m_g, m_l)
        o = _sdpa(cfg, q, k, v, mask)
    o = constrain(o, "heads")
    return o.reshape(B, S, cfg.n_heads * cfg.d_head) @ p["wo"]


def attention_decode(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray,
    kv_cache: Tuple[jnp.ndarray, jnp.ndarray],
    cur_pos: jnp.ndarray,                     # [B] int32: tokens so far
    positions: jnp.ndarray,                   # [B, 1] (or [3,B,1] mrope)
    is_global: bool | jnp.ndarray,
    active: jnp.ndarray,                      # [B] int32 (0 => don't write)
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-token decode with a ring-buffered, PER-SEQUENCE KV cache.

    kv_cache: (k, v) each [B, C, Hkv, dh]; C = full seq_len for global
    layers, swa_window for windowed layers.  Each sequence writes at its own
    cur_pos[b] % C (batched scatter); inactive rows scatter out-of-bounds
    with mode='drop' so their state is untouched.
    """
    B, S1, D = x.shape   # S1 == 1
    kc, vc = kv_cache
    C = kc.shape[1]
    q, k, v = _qkv(cfg, p, x)
    q, k = _position_embed(cfg, q, k, positions)
    slot = jnp.where(active > 0, cur_pos % C, C).astype(jnp.int32)  # C = OOB
    bidx = jnp.arange(B)
    kc = kc.at[bidx, slot].set(k[:, 0].astype(kc.dtype), mode="drop")
    vc = vc.at[bidx, slot].set(v[:, 0].astype(vc.dtype), mode="drop")
    # A ring slot t is valid if written (t <= pos) or the ring has wrapped.
    t = jnp.arange(C)
    valid = (t[None, :] <= cur_pos[:, None]) | (cur_pos[:, None] >= C)
    mask = valid[:, None, None, :]              # [B,1,1,C]
    o = _sdpa(cfg, q, kc, vc, mask)
    out = o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ p["wo"]
    return out, (kc, vc)


# ----------------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------------
def mlp(cfg: ModelConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"])
        u = x @ p["w_up"]
        h = constrain(g * u, "ffn")
        return h @ p["w_down"]
    if cfg.act == "relu2":   # squared ReLU (Nemotron-4 / Primer)
        h = jax.nn.relu(x @ p["w_up"])
        h = constrain(h * h, "ffn")
        return h @ p["w_down"]
    raise ValueError(cfg.act)


# ----------------------------------------------------------------------------
# parameter init
# ----------------------------------------------------------------------------
def init_attn_params(cfg: ModelConfig, key, dtype) -> Dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, hq * dh), dtype) * s,
        "wk": jax.random.normal(k2, (d, hkv * dh), dtype) * s,
        "wv": jax.random.normal(k3, (d, hkv * dh), dtype) * s,
        "wo": jax.random.normal(k4, (hq * dh, d), dtype) * (hq * dh) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def init_mlp_params(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None) -> Dict:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": jax.random.normal(k2, (d, ff), dtype) * d ** -0.5,
        "w_down": jax.random.normal(k3, (ff, d), dtype) * ff ** -0.5,
    }
    if cfg.act == "swiglu":
        p["w_gate"] = jax.random.normal(k1, (d, ff), dtype) * d ** -0.5
    return p
