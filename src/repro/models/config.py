"""Unified model configuration covering all 10 assigned architectures.

One dataclass drives dense GQA transformers, MoE, encoder-only audio, VLM
backbones with M-RoPE, pure SSM (Mamba2/SSD), and hybrid attn+SSM (Hymba).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    # --- attention ---------------------------------------------------------
    n_heads: int = 0               # query heads; 0 => attention-free layer
    n_kv_heads: int = 0
    d_head: int = 64
    attn: str = "full"             # full | swa | none
    swa_window: int = 1024
    global_attn_layers: Tuple[int, ...] = ()   # full-attn layers when attn=swa
    causal: bool = True            # False => encoder-only (no decode path)
    pos: str = "rope"              # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w freq split
    qk_norm: bool = False
    # --- MLP -----------------------------------------------------------------
    d_ff: int = 0                  # dense MLP width (0 => no dense MLP)
    act: str = "swiglu"            # swiglu | relu2
    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    router_aux_coef: float = 0.01
    moe_dispatch: str = "capacity"   # capacity (EP, ~active FLOPs) | dense
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm: bool = False              # present in every layer (pure or hybrid)
    ssm_state: int = 0             # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64         # P
    ssm_groups: int = 1            # G (B/C groups)
    ssm_conv: int = 4
    ssm_chunk: int = 64
    # --- embedding / frontend ---------------------------------------------------
    frontend: str = "token"        # token | audio | vision
    frontend_dim: int = 0          # stub embedding dim (0 => d_model)
    tie_embeddings: bool = False
    # --- numerics -----------------------------------------------------------------
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True             # activation checkpointing per layer
    scan_unroll: bool = False      # unroll layer scans (cost-probe lowering)

    # ---- derived -------------------------------------------------------------
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def ssm_conv_dim(self) -> int:
        return self.ssm_d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def has_attn(self) -> bool:
        return self.n_heads > 0 and self.attn != "none"

    @property
    def has_dense_mlp(self) -> bool:
        return self.d_ff > 0

    @property
    def has_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid with windowed attention."""
        if self.ssm and not self.has_attn:
            return True
        return self.ssm and self.attn == "swa"

    @property
    def can_decode(self) -> bool:
        return self.causal

    def layer_is_global(self, i: int) -> bool:
        return self.attn == "full" or i in self.global_attn_layers

    def n_params(self) -> int:
        """Analytic parameter count (for 6·N·D roofline math)."""
        d, dh = self.d_model, self.d_head
        n = self.vocab * d                                   # embed
        if not self.tie_embeddings:
            n += d * self.vocab                              # lm head
        per_layer = 0
        if self.has_attn:
            per_layer += d * self.n_heads * dh               # wq
            per_layer += 2 * d * self.n_kv_heads * dh        # wk, wv
            per_layer += self.n_heads * dh * d               # wo
        if self.has_dense_mlp:
            mults = 3 if self.act == "swiglu" else 2
            per_layer += mults * d * self.d_ff
        if self.has_moe:
            per_layer += d * self.n_experts                  # router
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
            if self.n_shared_experts:
                per_layer += 3 * d * self.shared_d_ff
        if self.ssm:
            di, g, N, h = (self.ssm_d_inner, self.ssm_groups,
                           self.ssm_state, self.ssm_heads)
            per_layer += d * (2 * di + 2 * g * N + h)        # in_proj
            per_layer += self.ssm_conv_dim * self.ssm_conv   # conv
            per_layer += 3 * h + di                          # A, D, dt_bias, norm
            per_layer += di * d                              # out_proj
        per_layer += 2 * d                                   # norms
        return n + self.n_layers * per_layer

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.has_moe:
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * self.moe_d_ff
        return full - inactive


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test-sized variant of the same family (layers/width shrunk)."""
    base = dict(
        n_layers=2,
        d_model=64,
        vocab=256,
        d_head=16,
        dtype="float32",
        remat=False,
    )
    if cfg.n_heads:
        base["n_heads"] = 4
        base["n_kv_heads"] = max(1, min(cfg.n_kv_heads, 2))
    if cfg.d_ff:
        base["d_ff"] = 128
    if cfg.n_experts:
        base.update(n_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=32,
                    moe_dispatch="dense")
        if cfg.n_shared_experts:
            base.update(n_shared_experts=1, shared_d_ff=64)
    if cfg.ssm:
        base.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.attn == "swa":
        base.update(swa_window=8, global_attn_layers=(0,))
    if cfg.frontend != "token":
        base["frontend_dim"] = 32
    if cfg.pos == "mrope":
        base["mrope_sections"] = (2, 3, 3)   # d_head 16 -> 8 freq slots
    base["name"] = cfg.name + "-smoke"
    return replace(cfg, **{**base, **overrides})
