"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) — chunked training
scan + single-token recurrent decode.

Implements the "minimal SSD" algorithm (paper Listing 1): intra-chunk
quadratic (duality with masked attention) + inter-chunk recurrent state pass.
Chunk length is cfg.ssm_chunk; matmul dims stay MXU-friendly (head dim P and
state N are multiples of 8/16 in all assigned configs).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .shardctx import constrain


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """[..., q] -> [..., q, q] lower-triangular segment sums."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(
    X: jnp.ndarray,       # [B, L, H, P]   (already multiplied by dt)
    A: jnp.ndarray,       # [B, L, H]      (dt * A, negative)
    Bm: jnp.ndarray,      # [B, L, G, N]
    Cm: jnp.ndarray,      # [B, L, G, N]
    chunk: int,
    init_state: jnp.ndarray | None = None,   # [B, H, P, N]
    unroll: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (Y [B, L, H, P], final_state [B, H, P, N])."""
    b, l, h, p = X.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert l % chunk == 0, (l, chunk)
    c = l // chunk
    rep = h // g
    X = X.reshape(b, c, chunk, h, p)
    A = A.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)      # [b,h,c,q]
    Bm = jnp.repeat(Bm.reshape(b, c, chunk, g, n), rep, axis=3)
    Cm = jnp.repeat(Cm.reshape(b, c, chunk, g, n), rep, axis=3)

    A = A.astype(jnp.float32)
    A_cs = jnp.cumsum(A, axis=-1)                            # [b,h,c,q]

    # 1. intra-chunk (diagonal blocks): quadratic "attention" form
    L = jnp.exp(segsum(A))                                   # [b,h,c,q,q]
    Y_diag = jnp.einsum(
        "bcshn,bczhn,bhcsz,bczhp->bcshp",
        Cm, Bm, L.astype(Cm.dtype), X,
    )

    # 2. chunk-final states
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)            # [b,h,c,q]
    states = jnp.einsum(
        "bczhn,bhcz,bczhp->bchpn", Bm,
        decay_states.astype(Bm.dtype), X,
    )                                                        # [b,c,h,p,n]

    # 3. inter-chunk recurrence over chunk-final states
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), states.dtype)
    chunk_decay = jnp.exp(A_cs[..., -1])                     # [b,h,c]

    def scan_fn(carry, inp):
        st, dec = inp                                        # [b,h,p,n], [b,h]
        new = carry * dec[..., None, None].astype(carry.dtype) + st
        return new, carry                                    # emit PRE-state

    states_t = jnp.moveaxis(states, 1, 0)                    # [c,b,h,p,n]
    # The inter-chunk recurrence is sequential: keep its inputs replicated
    # over any sequence-sharding axis (one gather beats c broadcasts).
    states_t = constrain(states_t, "ssm_states")
    decay_t = jnp.moveaxis(chunk_decay, 2, 0)                # [c,b,h]
    final_state, prev_states = jax.lax.scan(
        scan_fn, init_state, (states_t, decay_t),
        unroll=(states_t.shape[0] if unroll else 1),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # [b,c,h,p,n]

    # 4. state -> output within each chunk
    state_decay = jnp.exp(A_cs)                              # [b,h,c,q]
    Y_off = jnp.einsum(
        "bcshn,bchpn,bhcs->bcshp",
        Cm, prev_states, state_decay.astype(Cm.dtype),
    )
    Y = (Y_diag + Y_off).reshape(b, l, h, p)
    return Y, final_state


def init_ssm_params(cfg: ModelConfig, key, dtype) -> Dict:
    d = cfg.d_model
    di, g, N, h = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = cfg.ssm_conv_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * g * N + h    # z, x, B, C, dt
    return {
        "in_proj": jax.random.normal(k1, (d, in_dim), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(k2, (cfg.ssm_conv, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "D": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "ssm_norm": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(k4, (di, d), dtype) * di ** -0.5,
    }


def _split_in_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, g, N, h = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, xBC, dt = jnp.split(zxbcdt, [di, di + cfg.ssm_conv_dim], axis=-1)
    return z, xBC, dt


def _gated_rmsnorm(x, z, w, eps):
    x = x * jax.nn.silu(z)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def ssm_train(cfg: ModelConfig, p: Dict, u: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence Mamba2 mixer: u [B, L, D] -> [B, L, D]."""
    B, L, D = u.shape
    di, g, N, h = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    zxbcdt = u @ p["in_proj"]
    z, xBC, dt = _split_in_proj(cfg, zxbcdt)

    # causal depthwise conv over time (kernel k)
    k = cfg.ssm_conv
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i:i + L, :] * p["conv_w"][i][None, None, :] for i in range(k)
    ) + p["conv_b"]
    xBC = jax.nn.silu(conv)

    x, Bm, Cm = jnp.split(xBC, [di, di + g * N], axis=-1)
    x = x.reshape(B, L, h, P)
    Bm = Bm.reshape(B, L, g, N)
    Cm = Cm.reshape(B, L, g, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # [h]
    Y, _ = ssd_chunked(
        (x * dt[..., None].astype(x.dtype)),
        dt * A,                                              # [B,L,h]
        Bm, Cm, cfg.ssm_chunk,
        unroll=cfg.scan_unroll,
    )
    Y = Y + x * p["D"][None, None, :, None]
    y = _gated_rmsnorm(Y.reshape(B, L, di), z, p["ssm_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    return {
        "state": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype
        ),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_conv_dim), dtype),
    }


def ssm_decode(
    cfg: ModelConfig, p: Dict, u: jnp.ndarray, cache: Dict,
    active: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, Dict]:
    """Single-token recurrent step: u [B, 1, D].  Rows with active==0 keep
    their state unchanged (mixed-length serving batches)."""
    B = u.shape[0]
    di, g, N, h = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    zxbcdt = u[:, 0, :] @ p["in_proj"]
    z, xBC, dt = _split_in_proj(cfg, zxbcdt[:, None, :])
    z, xBC, dt = z[:, 0], xBC[:, 0], dt[:, 0]

    # rolling conv buffer
    win = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # [B,k,cd]
    conv = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    new_conv = win[:, 1:, :]
    xBC = jax.nn.silu(conv)

    x, Bm, Cm = jnp.split(xBC, [di, di + g * N], axis=-1)
    x = x.reshape(B, h, P)
    Bm = jnp.repeat(Bm.reshape(B, g, N), h // g, axis=1)     # [B,h,N]
    Cm = jnp.repeat(Cm.reshape(B, g, N), h // g, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                     # [B,h]
    st = cache["state"]
    st = st * dA[..., None, None].astype(st.dtype) + jnp.einsum(
        "bhp,bhn->bhpn", (x * dt[..., None].astype(x.dtype)), Bm
    ).astype(st.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", st, Cm)
    y = y + x * p["D"][None, :, None]
    y = _gated_rmsnorm(y.reshape(B, di), z, p["ssm_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    if active is not None:
        keep = (active > 0)
        st = jnp.where(keep[:, None, None, None], st, cache["state"])
        new_conv = jnp.where(keep[:, None, None], new_conv, cache["conv"])
    return out, {"state": st, "conv": new_conv}
