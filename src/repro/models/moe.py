"""Mixture-of-Experts layer: top-k softmax routing, optional shared experts
(Qwen-MoE style), dense one-hot dispatch (einsum over the expert axis, which
shards cleanly over the "model" mesh axis = expert parallelism; GSPMD emits
the all-to-all-equivalent collectives).

Load-balancing aux loss follows Switch Transformer (fraction-of-tokens x
mean-router-prob per expert).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_mlp_params
from .shardctx import constrain


def init_moe_params(cfg: ModelConfig, key, dtype) -> Dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(k1, (d, e), dtype) * d ** -0.5,
        "w_gate": jax.random.normal(k2, (e, d, ff), dtype) * d ** -0.5,
        "w_up": jax.random.normal(k3, (e, d, ff), dtype) * d ** -0.5,
        "w_down": jax.random.normal(k4, (e, ff, d), dtype) * ff ** -0.5,
    }
    if cfg.n_shared_experts:
        shared_cfg_ff = cfg.shared_d_ff
        p["shared"] = init_mlp_params(cfg, k5, dtype, d_ff=shared_cfg_ff)
    return p


def moe_mlp(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (x @ p["router"]).astype(jnp.float32)            # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                    # [B,S,K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)    # renormalize

    # Dense dispatch: combine [B,S,E] = sum_k onehot(top_i_k) * top_p_k
    onehot = jax.nn.one_hot(top_i, E, dtype=x.dtype)          # [B,S,K,E]
    combine = jnp.einsum("bske,bsk->bse", onehot, top_p.astype(x.dtype))

    # Expert computation on the full token set (dense einsum over E):
    #   h_e = act(x @ Wg_e) * (x @ Wu_e);  y_e = h_e @ Wd_e
    # then weighted-combined.  The E axis shards over "model" (EP); the
    # dispatch einsums become the a2a-equivalent collectives in HLO.
    g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    h = constrain(jax.nn.silu(g) * u, "moe")
    y = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    out = jnp.einsum("bsed,bse->bsd", y, combine)

    if cfg.n_shared_experts:
        sp = p["shared"]
        sg = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + sg @ sp["w_down"]

    # Switch-style load-balance loss.
    frac_tokens = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )                                                         # [E]
    frac_probs = jnp.mean(probs, axis=(0, 1))                 # [E]
    aux = jnp.sum(frac_tokens * frac_probs) * E
    return out, aux


def moe_mlp_capacity(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-bounded gather/scatter dispatch (GShard-style; the production
    path for the large MoE configs).

    Tokens scatter into per-expert buffers of capacity
    C = ceil(K * N * cf / E) (overflow drops); experts run batched GEMMs over
    their buffers; results gather back weighted by router probs.  FLOPs stay
    ~top_k-active (vs E/K-times for dense dispatch); the expert axis shards
    over "model" (EP), so the scatter/gather become the all-to-all-style
    collectives in HLO.

    Slot assignment avoids the [N*K, E] cumsum cube: top-k experts per token
    are DISTINCT, so a token's slot in expert e is just the exclusive-over-
    tokens running count base_prev[n, e].
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, D)
    logits = (xf @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                    # [N, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    C = int(max(1, round(K * N * cfg.moe_capacity_factor / E)))
    C = -(-C // 64) * 64   # round up: capacity dim stays mesh-shardable
    tok_onehot = jax.nn.one_hot(top_i, E, dtype=jnp.int32).sum(axis=1)  # [N,E]
    base = jnp.cumsum(tok_onehot, axis=0) - tok_onehot        # exclusive [N,E]
    slot = jnp.take_along_axis(base, top_i, axis=1)           # [N, K]
    keep = slot < C

    flat_e = jnp.where(keep, top_i, 0).reshape(-1)            # [N*K]
    flat_s = jnp.where(keep, slot, 0).reshape(-1)
    flat_w = jnp.where(keep, top_p, 0.0).reshape(-1)
    src = jnp.repeat(xf, K, axis=0)                           # [N*K, D]
    src = jnp.where(keep.reshape(-1)[:, None], src, 0)

    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[flat_e, flat_s].add(src.astype(x.dtype))
    buf = constrain(buf, "moe_buf")
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = constrain(jax.nn.silu(g) * u, "moe_hidden")
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    gathered = y[flat_e, flat_s]                              # [N*K, D]
    outf = jnp.zeros((N, D), jnp.float32)
    tok_idx = jnp.repeat(jnp.arange(N), K)
    outf = outf.at[tok_idx].add(
        gathered.astype(jnp.float32) * flat_w[:, None]
    )
    out = outf.reshape(B, S, D).astype(x.dtype)

    if cfg.n_shared_experts:
        sp = p["shared"]
        sg = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + sg @ sp["w_down"]

    frac_tokens = jnp.mean(tok_onehot.astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac_tokens * frac_probs) * E
    return out, aux


def moe_mlp_shardmap(cfg: ModelConfig, p: Dict, x: jnp.ndarray):
    """Expert-parallel MoE with an EXPLICIT all-to-all (shard_map).

    GSPMD cannot prove locality of the capacity dispatch's data-dependent
    scatters and falls back to replicating token buffers ("involuntary full
    rematerialization"), which made qwen3 train_4k 6x collective-bound.
    Here the routing is done per-shard with plain JAX, and the only
    cross-device traffic is the tiled lax.all_to_all of the [E, C_l, D]
    capacity buffers over the "model" axis (plus the ZeRO weight gather over
    "data").  Differentiable end to end (a2a transposes to a2a).

    Requires the 'moe_ep' marker rule (launch/sharding.py installs it) to
    know the mesh and the residual activation layout.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from .shardctx import get_rule

    marker = get_rule("moe_ep")
    res_rule = get_rule("residual")
    mesh = marker.mesh
    x_spec = res_rule.spec
    tp_axis = "model"
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))[tp_axis]
    E, K = cfg.n_experts, cfg.top_k
    assert E % tp == 0, "shard_map EP needs divisible experts"
    e_local = E // tp
    data_axes = tuple(a for a in mesh.axis_names if a != tp_axis)

    w_spec3 = P(tp_axis, "data", None)   # [E, D, F] as stored (EP x FSDP)
    wd_spec = P(tp_axis, None, "data")

    def local_fn(xl, router, wg, wu, wd):
        # Gather the FSDP'd D-dim of this device's experts (ZeRO-at-use).
        wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        router = jax.lax.all_gather(router, "data", axis=0, tiled=True)
        bl, sl, d = xl.shape
        n = bl * sl
        xf = xl.reshape(n, d)
        logits = (xf @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        C = int(max(8, -(-int(K * n * cfg.moe_capacity_factor / E) // 8) * 8))
        tok_onehot = jax.nn.one_hot(top_i, E, dtype=jnp.int32).sum(axis=1)
        base = jnp.cumsum(tok_onehot, axis=0) - tok_onehot
        slot = jnp.take_along_axis(base, top_i, axis=1)
        keep = slot < C
        flat_e = jnp.where(keep, top_i, 0).reshape(-1)
        flat_s = jnp.where(keep, slot, 0).reshape(-1)
        flat_w = jnp.where(keep, top_p, 0.0).reshape(-1)
        src = jnp.repeat(xf, K, axis=0)
        src = jnp.where(keep.reshape(-1)[:, None], src, 0)
        buf = jnp.zeros((E, C, d), xl.dtype)
        buf = buf.at[flat_e, flat_s].add(src.astype(xl.dtype))
        # all-to-all: send each expert-shard its slice, receive all source
        # shards' buffers for MY experts: [E, C, D] -> [e_local, tp*C, D].
        recv = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=1,
                                  tiled=True)
        g = jnp.einsum("ecd,edf->ecf", recv, wg)
        u = jnp.einsum("ecd,edf->ecf", recv, wu)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
        # route results back: [e_local, tp*C, D] -> [E, C, D]
        back = jax.lax.all_to_all(y, tp_axis, split_axis=1, concat_axis=0,
                                  tiled=True)
        gathered = back[flat_e, flat_s]
        outf = jnp.zeros((n, d), jnp.float32)
        tok_idx = jnp.repeat(jnp.arange(n), K)
        outf = outf.at[tok_idx].add(
            gathered.astype(jnp.float32) * flat_w[:, None]
        )
        out = outf.reshape(bl, sl, d).astype(xl.dtype)
        frac_tokens = jnp.mean(tok_onehot.astype(jnp.float32), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        aux = jnp.sum(frac_tokens * frac_probs) * E
        aux = jax.lax.pmean(aux, mesh.axis_names)
        return out, aux

    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P("data", None), w_spec3, w_spec3, wd_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared_experts:
        sp = p["shared"]
        sg = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + sg @ sp["w_down"]
    return out, aux


def moe_forward(cfg: ModelConfig, p: Dict, x: jnp.ndarray):
    from .shardctx import get_rule

    if (cfg.moe_dispatch == "capacity" and get_rule("moe_ep") is not None
            and cfg.n_experts and get_rule("residual") is not None):
        try:
            tp = dict(zip(get_rule("moe_ep").mesh.axis_names,
                          get_rule("moe_ep").mesh.devices.shape))["model"]
        except Exception:
            tp = 0
        if tp and cfg.n_experts % tp == 0:
            return moe_mlp_shardmap(cfg, p, x)
    if cfg.moe_dispatch == "capacity":
        return moe_mlp_capacity(cfg, p, x)
    return moe_mlp(cfg, p, x)
