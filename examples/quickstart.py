"""Quickstart: CURP in 60 seconds.

Spins up an in-process CURP cluster (1 master, 3 backups, 3 witnesses),
shows the 1-RTT fast path, the commutativity conflict path, a master crash
with witness replay, and a consistent read from a backup (§A.1).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import LocalCluster


def main() -> None:
    cluster = LocalCluster(f=3, sync_batch=50)
    client = cluster.new_client()

    print("== 1. fast path: commutative updates complete in 1 RTT ==")
    for i in range(5):
        out = cluster.update(client, client.op_set(f"user{i}", f"v{i}"))
        print(f"  SET user{i}: rtts={out.rtts} fast={out.fast_path} "
              f"witness_accepts={out.witness_accepts}")

    print("\n== 2. conflict: same key twice -> master syncs, 2 RTTs ==")
    cluster.update(client, client.op_set("hot", 1))
    out = cluster.update(client, client.op_set("hot", 2))
    print(f"  second SET hot: rtts={out.rtts} synced_path={out.synced_path}")

    print("\n== 3. crash the master; recover from backups + ONE witness ==")
    for i in range(7):
        cluster.update(client, client.op_incr("counter"))
    report = cluster.crash_master()
    print(f"  recovery: restored {report.restored_log_entries} synced ops, "
          f"replayed {report.replayed} witnessed ops "
          f"(epoch -> {report.new_epoch})")
    v = cluster.read(client, client.op_get("counter")).value
    print(f"  counter after recovery = {v} (expected 7)")
    assert v == 7

    print("\n== 4. consistent backup reads (§A.1) ==")
    cluster.update(client, client.op_set("geo", "fresh"))
    cluster.sync_now()
    v, from_backup = cluster.read_from_backup(client, client.op_get("geo"))
    print(f"  synced key: value={v!r} served_by_backup={from_backup}")
    cluster.update(client, client.op_set("geo", "newer"))
    v, from_backup = cluster.read_from_backup(client, client.op_get("geo"))
    print(f"  unsynced key: value={v!r} served_by_backup={from_backup} "
          f"(witness vetoed the stale backup)")
    print("\nOK")


if __name__ == "__main__":
    main()
