"""END-TO-END DRIVER: serve a small LM with batched requests through the
CURP-replicated runtime, crash the serving master mid-flight, recover, and
verify the token streams continue exactly where they left off.

This is the paper's kind of system (a low-latency replicated store) hosting
the framework's kind of workload (batched LM decoding): session commits ride
CURP's 1-RTT fast path because sessions are disjoint keys.

    PYTHONPATH=src python examples/serve_curp.py
"""
import time

from repro.configs import ARCHS
from repro.models.config import reduced
from repro.serving import CurpServeDriver, ServeConfig


def main() -> None:
    cfg = reduced(ARCHS["llama3.2-1b"])
    print(f"model: {cfg.name} ({cfg.n_params()/1e6:.1f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model})")
    sc = ServeConfig(max_batch=8, max_seq=96, f=3, sync_batch=50)
    driver = CurpServeDriver(cfg, sc, seed=7)

    print("\n== submit a batch of requests ==")
    prompts = {
        "alice": [11, 42, 7],
        "bob": [3, 3, 8, 1],
        "carol": [99],
        "dave": [5, 6, 7, 8, 9],
    }
    for sid, p in prompts.items():
        driver.submit(sid, p)
        print(f"  session {sid}: prompt {p}")

    print("\n== batched decoding (12 tokens each) ==")
    t0 = time.time()
    driver.generate(12)
    dt = time.time() - t0
    for sid, s in driver.sessions.items():
        print(f"  {sid}: {s.tokens}")
    print(f"  {driver.tokens_served} tokens in {dt:.2f}s "
          f"({driver.tokens_served/dt:.0f} tok/s on CPU)")
    print(f"  CURP commits: {driver.store.fast_commits} fast (1 RTT), "
          f"{driver.store.slow_commits} slow")

    snapshot = {sid: list(s.tokens) for sid, s in driver.sessions.items()}

    print("\n== CRASH the serving master ==")
    rep = driver.crash_and_recover()
    print(f"  recovered {rep['recovered_sessions']} sessions "
          f"(witness replayed {rep['replayed_ops']} unsynced commits); "
          f"KV caches rebuilt by re-prefill")
    for sid in snapshot:
        assert driver.sessions[sid].tokens == snapshot[sid]

    print("\n== continue decoding after recovery ==")
    driver.generate(6)
    for sid, s in driver.sessions.items():
        cont = s.tokens[len(snapshot[sid]):]
        print(f"  {sid}: +{cont}")
    print("\nOK — serving survived a master crash with zero lost tokens")


if __name__ == "__main__":
    main()
