"""Geo-replication (§A.1 + §3.1): 1 wide-area RTT updates and 0 wide-area
RTT strongly-consistent reads from a LOCAL backup, gated by a LOCAL witness.

Simulated topology: client + backup + witness in region A; master in region
B (50 ms away).  CURP's witness commutativity check tells the client whether
the local backup's value can be stale.

    PYTHONPATH=src python examples/georeplication.py
"""
from repro.core import LocalCluster

WAN_RTT_MS = 50.0


def main() -> None:
    cluster = LocalCluster(f=3, sync_batch=50)
    client = cluster.new_client()

    def wan_cost(rtts: int) -> float:
        return rtts * WAN_RTT_MS

    print("== geo update: 1 wide-area RTT (vs 2 for primary-backup) ==")
    out = cluster.update(client, client.op_set("profile:alice", "v1"))
    print(f"  CURP:          {wan_cost(out.rtts):.0f} ms "
          f"(master exec + parallel witness records)")
    print(f"  primary-backup: {wan_cost(2):.0f} ms (order, then replicate)")

    print("\n== geo read of a SYNCED key: 0 wide-area RTTs ==")
    cluster.sync_now()
    v, local = cluster.read_from_backup(client, client.op_get("profile:alice"))
    print(f"  local witness commutes -> read {v!r} from the LOCAL backup "
          f"({0 if local else wan_cost(1):.0f} ms wide-area)")

    print("\n== geo read of an UNSYNCED key: witness vetoes the backup ==")
    cluster.update(client, client.op_set("profile:alice", "v2"))

    # First show what a NAIVE local read would return right now (stale!):
    from repro.core.store import KVStore

    naive = KVStore()
    for e in cluster.backups[0].get_log():
        naive.execute(e.op)
    stale = naive.get("profile:alice")
    print(f"  naive local backup read right now: {stale!r}  (STALE)")
    assert stale == "v1"

    v, local = cluster.read_from_backup(client, client.op_get("profile:alice"))
    print(f"  CURP: local witness holds a record for the key -> must read "
          f"from the master: {v!r} ({wan_cost(1):.0f} ms)")
    assert v == "v2" and not local
    print("\nOK")


if __name__ == "__main__":
    main()
