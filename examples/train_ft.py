"""CURP-FT: fault-tolerant training with 1-RTT durable steps.

Trains a reduced model twice: once uninterrupted, once with a kill at an
arbitrary step followed by CURP recovery (newest backup + witness-journal
replay).  The two runs end BIT-EXACT — the witness journal (~100 B/step)
plus batched backup syncs give the durability of per-step checkpoints at a
tiny fraction of the bandwidth.

    PYTHONPATH=src python examples/train_ft.py
"""
import shutil
import time

from repro.configs import ARCHS
from repro.data.pipeline import DataConfig
from repro.ft import FTConfig, FaultTolerantTrainer
from repro.models.config import reduced


def main() -> None:
    cfg = reduced(ARCHS["smollm-360m"])
    data = DataConfig(batch=4, seq=64)
    steps, crash_at = 30, 23
    print(f"model: {cfg.name} ({cfg.n_params()/1e6:.1f}M params); "
          f"{steps} steps, backup sync every 10, crash at {crash_at}")

    shutil.rmtree("/tmp/curp_ft_ref", ignore_errors=True)
    shutil.rmtree("/tmp/curp_ft_crash", ignore_errors=True)

    print("\n== run A: uninterrupted ==")
    a = FaultTolerantTrainer(cfg, data,
                             FTConfig(f=3, sync_every=10,
                                      workdir="/tmp/curp_ft_ref"))
    t0 = time.time()
    a.train(steps)
    print(f"  loss: {a.metrics_log[0]['loss']:.3f} -> "
          f"{a.metrics_log[-1]['loss']:.3f}  ({time.time()-t0:.1f}s)")

    print(f"\n== run B: kill the master at step {crash_at} ==")
    b = FaultTolerantTrainer(cfg, data,
                             FTConfig(f=3, sync_every=10,
                                      workdir="/tmp/curp_ft_crash"))
    b.train(crash_at)
    b.crash()
    print("  master killed: params/optimizer state GONE from memory")
    rep = b.recover()
    print(f"  recovery: restored backup @step {rep['restored_step']}, "
          f"replayed {rep['replayed']} journaled steps "
          f"-> resumed at {rep['resumed_at']}")
    b.train(steps - b.step)

    da, db = a.params_digest(), b.params_digest()
    print(f"\n  run A digest: {da[:16]}…\n  run B digest: {db[:16]}…")
    assert da == db
    print("\nOK — BIT-EXACT recovery: crash+replay == uninterrupted run")


if __name__ == "__main__":
    main()
